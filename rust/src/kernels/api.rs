//! The uniform kernel interface of the native execution stack.
//!
//! Everything the runtime executes reduces to one primitive —
//! [`AttentionKernel`]: *solve a single-head attention problem over
//! contiguous row-major `[n, d]` Q/K/V, scratch from a [`Workspace`],
//! output into `[n, d]`*. Around it:
//!
//! - [`KernelRegistry`]: name-keyed kernel lookup, replacing string-matched
//!   dispatch inside the backend. `attn.mita` and `attn.dense` are the
//!   default entries; new kernels register without touching the backend.
//! - [`AttnProblem`]: shape descriptor of a batched multi-head problem
//!   (batch, heads, n, dim, fused-vs-separate layout, valid rows).
//! - [`run_batched`]: decomposes a problem into (example × head) work
//!   items scheduled across [`crate::kernels::par`], each on a pooled
//!   per-thread [`Workspace`], then scatters head results back to
//!   model-dim layout. Padding rows are zeroed, never computed.
//! - [`MitaStats`]: routing statistics accumulated across kernel calls and
//!   surfaced through the backend into serve reports.

use crate::kernels::dense::dense_attention;
use crate::kernels::linalg::{gather_head, scatter_head};
use crate::kernels::mita::{mita_attention, MitaKernelConfig};
use crate::kernels::par::par_chunks_mut;
use crate::kernels::workspace::{Workspace, WorkspacePool};

/// Registry / op name of the MiTA kernel.
pub const OP_ATTN_MITA: &str = "attn.mita";
/// Registry / op name of the dense-baseline kernel.
pub const OP_ATTN_DENSE: &str = "attn.dense";

// ---------------------------------------------------------------------------
// Routing statistics
// ---------------------------------------------------------------------------

/// Routing / packing statistics accumulated across MiTA kernel calls.
///
/// A fresh `MitaStats::default()` passed to one kernel call records exactly
/// that call; the batched executor merges per-thread accumulators into one
/// per-backend total, and the serve loop brackets a run with resetting
/// snapshots to get per-run numbers. Kernels without routing (dense) leave
/// it untouched, so `queries == 0` means "no MiTA work recorded".
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MitaStats {
    /// Kernel invocations recorded (one per (example × head) work item).
    pub calls: usize,
    /// Total queries routed.
    pub queries: usize,
    /// Queries that exceeded their expert's capacity and were served by
    /// the exact unpacked fallback pass.
    pub overflow: usize,
    /// Query-slot capacity per expert of the most recent call.
    pub cap: usize,
    /// Worst single-call routing skew seen so far, in thousandths:
    /// `max_count · m / n` of the most skewed call (1000 = perfectly
    /// balanced). Kept as an integer so the struct stays `Eq`.
    pub peak_imbalance_milli: usize,
    /// Queries routed to each expert (element-wise sum across calls).
    pub expert_counts: Vec<usize>,
}

impl MitaStats {
    /// Record one kernel call's routing outcome.
    pub fn record(&mut self, cap: usize, overflow: usize, counts: &[usize]) {
        let routed: usize = counts.iter().sum();
        self.calls += 1;
        self.queries += routed;
        self.overflow += overflow;
        self.cap = cap;
        if routed > 0 {
            let max = counts.iter().copied().max().unwrap_or(0);
            let imbalance = max * counts.len() * 1000 / routed;
            self.peak_imbalance_milli = self.peak_imbalance_milli.max(imbalance);
        }
        if self.expert_counts.len() < counts.len() {
            self.expert_counts.resize(counts.len(), 0);
        }
        for (acc, &c) in self.expert_counts.iter_mut().zip(counts) {
            *acc += c;
        }
    }

    /// Fold another accumulator into this one.
    pub fn merge(&mut self, other: &MitaStats) {
        self.calls += other.calls;
        self.queries += other.queries;
        self.overflow += other.overflow;
        self.cap = self.cap.max(other.cap);
        self.peak_imbalance_milli = self.peak_imbalance_milli.max(other.peak_imbalance_milli);
        if self.expert_counts.len() < other.expert_counts.len() {
            self.expert_counts.resize(other.expert_counts.len(), 0);
        }
        for (acc, &c) in self.expert_counts.iter_mut().zip(&other.expert_counts) {
            *acc += c;
        }
    }

    /// Clear every counter, keeping allocated capacity.
    pub fn reset(&mut self) {
        self.calls = 0;
        self.queries = 0;
        self.overflow = 0;
        self.cap = 0;
        self.peak_imbalance_milli = 0;
        self.expert_counts.clear();
    }

    /// Fraction of queries served by the overflow fallback pass.
    pub fn overflow_fraction(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.overflow as f64 / self.queries as f64
        }
    }

    /// Worst single-call expert load relative to perfect balance: 1.0
    /// means every expert received `n / m` in every call; larger values
    /// mean routing skew. Tracked per call (not on the aggregated counts,
    /// where opposite skews across heads would average out to "balanced").
    pub fn load_imbalance(&self) -> f64 {
        self.peak_imbalance_milli as f64 / 1000.0
    }
}

/// Per-transformer-block timing + routing profile of model forwards.
///
/// One entry per block: wall time split between the attention path and
/// the MLP path, plus that block's own [`MitaStats`] (instead of the one
/// merged accumulator the plain forward reports). Produced by
/// `MitaModel::forward_profiled`, accumulated per backend, and surfaced
/// through traces (`/v1/trace`) and per-layer metrics series.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BlockProfile {
    /// Attention-path wall time (pre-LN + Q/K/V projection + kernel +
    /// output projection + residual), nanoseconds.
    pub attn_ns: u64,
    /// MLP-path wall time (pre-LN + GELU MLP + residual), nanoseconds.
    pub mlp_ns: u64,
    /// Routing statistics of this block alone.
    pub stats: MitaStats,
}

impl BlockProfile {
    /// Fold another profile of the same block into this one.
    pub fn merge(&mut self, other: &BlockProfile) {
        self.attn_ns += other.attn_ns;
        self.mlp_ns += other.mlp_ns;
        self.stats.merge(&other.stats);
    }
}

/// Merge per-block profiles element-wise (index = block), growing `into`
/// if `add` covers more blocks.
pub fn merge_block_profiles(into: &mut Vec<BlockProfile>, add: &[BlockProfile]) {
    if into.len() < add.len() {
        into.resize(add.len(), BlockProfile::default());
    }
    for (acc, b) in into.iter_mut().zip(add) {
        acc.merge(b);
    }
}

// ---------------------------------------------------------------------------
// The kernel trait + registry
// ---------------------------------------------------------------------------

/// One attention kernel: solves a single-head `[n, d]` problem.
///
/// Implementations must give back every workspace buffer they take and be
/// allocation-free once the workspace is warm — that contract is what lets
/// the batched executor run thousands of work items without touching the
/// allocator.
pub trait AttentionKernel: Send + Sync {
    /// Registry / op name (e.g. `"attn.mita"`).
    fn name(&self) -> &'static str;

    /// Compute attention for contiguous row-major `[n, d]` Q/K/V into the
    /// `[n, d]` output, recording routing stats (kernels without routing
    /// leave `stats` untouched).
    #[allow(clippy::too_many_arguments)]
    fn run(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        ws: &mut Workspace,
        out: &mut [f32],
        stats: &mut MitaStats,
    );
}

/// [`AttentionKernel`] over the MiTA forward pass
/// ([`crate::kernels::mita::mita_attention`]).
#[derive(Debug, Clone)]
pub struct MitaKernel {
    /// Shape-independent MiTA parameters (m, k, capacity policy).
    pub cfg: MitaKernelConfig,
}

impl AttentionKernel for MitaKernel {
    fn name(&self) -> &'static str {
        OP_ATTN_MITA
    }

    fn run(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        ws: &mut Workspace,
        out: &mut [f32],
        stats: &mut MitaStats,
    ) {
        mita_attention(q, k, v, n, d, &self.cfg, ws, out, stats);
    }
}

/// [`AttentionKernel`] over the dense O(N²) baseline
/// ([`crate::kernels::dense::dense_attention`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct DenseKernel;

impl AttentionKernel for DenseKernel {
    fn name(&self) -> &'static str {
        OP_ATTN_DENSE
    }

    fn run(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        ws: &mut Workspace,
        out: &mut [f32],
        _stats: &mut MitaStats,
    ) {
        dense_attention(q, k, v, n, d, ws, out);
    }
}

/// Name-keyed kernel registry: the backend resolves ops here instead of
/// string-matching inside `run`.
#[derive(Default)]
pub struct KernelRegistry {
    kernels: Vec<Box<dyn AttentionKernel>>,
}

impl KernelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        KernelRegistry::default()
    }

    /// The standard kernel set: `attn.mita` (with `cfg`) and `attn.dense`.
    pub fn with_defaults(cfg: MitaKernelConfig) -> Self {
        let mut registry = KernelRegistry::new();
        registry.register(Box::new(MitaKernel { cfg }));
        registry.register(Box::new(DenseKernel));
        registry.register(Box::new(crate::decode::CausalMitaKernel { cfg }));
        registry.register(Box::new(crate::decode::CausalDenseKernel));
        registry
    }

    /// Add a kernel, replacing any existing entry with the same name.
    pub fn register(&mut self, kernel: Box<dyn AttentionKernel>) {
        match self.kernels.iter().position(|k| k.name() == kernel.name()) {
            Some(i) => self.kernels[i] = kernel,
            None => self.kernels.push(kernel),
        }
    }

    /// Look up a kernel by registry name.
    pub fn get(&self, name: &str) -> Option<&dyn AttentionKernel> {
        self.kernels.iter().find(|k| k.name() == name).map(|k| k.as_ref())
    }

    /// Like [`KernelRegistry::get`], but a miss reports the available
    /// names — the message every dispatch site used to hand-roll.
    pub fn resolve(&self, name: &str) -> Result<&dyn AttentionKernel, String> {
        self.get(name).ok_or_else(|| {
            format!("no kernel {name:?} registered (available: {})", self.names().join(", "))
        })
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.kernels.iter().map(|k| k.name()).collect()
    }
}

// ---------------------------------------------------------------------------
// Problem descriptor + input views
// ---------------------------------------------------------------------------

/// Layout of the Q/K/V inputs of an [`AttnProblem`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QkvLayout {
    /// One `[b, 3, n, dim]` buffer with Q/K/V stacked on axis 1.
    Fused,
    /// Three `[b, n, dim]` buffers.
    Separate,
}

/// Shape descriptor of one batched multi-head attention problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnProblem {
    /// Batch rows present in the buffers (including padding).
    pub batch: usize,
    /// Attention heads; `dim` splits into `heads` column blocks.
    pub heads: usize,
    /// Sequence length.
    pub n: usize,
    /// Model dimension (`heads · head_dim`).
    pub dim: usize,
    /// Input layout (fused vs separate Q/K/V).
    pub layout: QkvLayout,
    /// Leading batch rows that carry real data; the trailing
    /// `batch - valid` rows are padding — never computed, never written.
    pub valid: usize,
}

impl AttnProblem {
    /// A problem over `batch` real examples (no padding).
    pub fn new(batch: usize, heads: usize, n: usize, dim: usize, layout: QkvLayout) -> Self {
        AttnProblem { batch, heads, n, dim, layout, valid: batch }
    }

    /// Mark trailing rows as padding: only the first `valid` examples are
    /// computed.
    pub fn with_valid(mut self, valid: usize) -> Self {
        self.valid = valid;
        self
    }

    /// Per-head feature dimension.
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// (example × head) work items the batched executor schedules.
    pub fn work_items(&self) -> usize {
        self.valid * self.heads
    }

    /// Floats per example per tensor (`n · dim`).
    pub fn example_len(&self) -> usize {
        self.n * self.dim
    }

    /// Structural validity: heads divide dim, valid rows within the batch.
    pub fn validate(&self) -> Result<(), String> {
        if self.heads == 0 || self.dim % self.heads != 0 {
            return Err(format!("model dim {} not divisible by {} heads", self.dim, self.heads));
        }
        if self.valid > self.batch {
            return Err(format!("valid rows {} exceed batch {}", self.valid, self.batch));
        }
        Ok(())
    }
}

/// Borrowed view of a problem's Q/K/V input buffers.
#[derive(Debug, Clone, Copy)]
pub enum QkvData<'a> {
    /// `[b, 3, n, dim]` with Q/K/V stacked on axis 1.
    Fused(&'a [f32]),
    /// Three `[b, n, dim]` buffers.
    Separate {
        /// Queries.
        q: &'a [f32],
        /// Keys.
        k: &'a [f32],
        /// Values.
        v: &'a [f32],
    },
}

impl<'a> QkvData<'a> {
    /// The layout this view carries.
    pub fn layout(&self) -> QkvLayout {
        match self {
            QkvData::Fused(_) => QkvLayout::Fused,
            QkvData::Separate { .. } => QkvLayout::Separate,
        }
    }

    /// Check buffer lengths and layout against a problem descriptor.
    pub fn check(&self, prob: &AttnProblem) -> Result<(), String> {
        if self.layout() != prob.layout {
            return Err(format!(
                "data layout {:?} != problem layout {:?}",
                self.layout(),
                prob.layout
            ));
        }
        let per = prob.example_len();
        match self {
            QkvData::Fused(data) => {
                if data.len() != prob.batch * 3 * per {
                    return Err(format!(
                        "fused buffer holds {} floats, want {} for [b={}, 3, n={}, dim={}]",
                        data.len(),
                        prob.batch * 3 * per,
                        prob.batch,
                        prob.n,
                        prob.dim
                    ));
                }
            }
            QkvData::Separate { q, k, v } => {
                for (name, buf) in [("q", q), ("k", k), ("v", v)] {
                    if buf.len() != prob.batch * per {
                        return Err(format!(
                            "{name} holds {} floats, want {} for [b={}, n={}, dim={}]",
                            buf.len(),
                            prob.batch * per,
                            prob.batch,
                            prob.n,
                            prob.dim
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Contiguous (q, k, v) slices of example `i`, each `n·dim` floats.
    pub fn example(&self, prob: &AttnProblem, i: usize) -> (&'a [f32], &'a [f32], &'a [f32]) {
        let per = prob.example_len();
        match *self {
            QkvData::Fused(data) => {
                let block = &data[i * 3 * per..(i + 1) * 3 * per];
                (&block[..per], &block[per..2 * per], &block[2 * per..])
            }
            QkvData::Separate { q, k, v } => (
                &q[i * per..(i + 1) * per],
                &k[i * per..(i + 1) * per],
                &v[i * per..(i + 1) * per],
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Batched parallel execution
// ---------------------------------------------------------------------------

/// Execute `prob` with `kernel` by decomposing it into (example × head)
/// work items run in parallel, each on a pooled per-thread workspace.
///
/// `headout` is a caller-owned staging buffer (head-major `[valid·heads,
/// n, head_dim]`) reused across calls; `out` receives `[batch, n, dim]`
/// with padding rows (`valid..batch`) zero-filled and never computed.
/// Kernel routing stats accumulate into `stats`.
///
/// Parallelism granularity is deliberately the work item: the kernels
/// themselves are serial (that is what makes them zero-alloc over one
/// workspace), so a `valid·heads = 1` problem runs on one thread. Serving
/// throughput comes from batching — the batcher packs requests precisely
/// so this fan-out has items to spread across cores.
///
/// The pool must not be shared with another concurrent `run_batched` call
/// while stats are being collected (the backend serializes runs).
#[allow(clippy::too_many_arguments)]
pub fn run_batched(
    kernel: &dyn AttentionKernel,
    prob: &AttnProblem,
    data: &QkvData<'_>,
    pool: &WorkspacePool,
    headout: &mut Vec<f32>,
    out: &mut [f32],
    stats: &mut MitaStats,
) {
    if let Err(e) = prob.validate() {
        panic!("invalid attention problem: {e}");
    }
    if let Err(e) = data.check(prob) {
        panic!("attention inputs do not match problem: {e}");
    }
    let (heads, n, dim) = (prob.heads, prob.n, prob.dim);
    let (dh, per) = (prob.head_dim(), prob.example_len());
    assert_eq!(out.len(), prob.batch * per, "out must be [batch, n, dim]");

    // Padding rows are zeroed up front and skipped below.
    out[prob.valid * per..].fill(0.0);
    if prob.valid == 0 || per == 0 {
        return;
    }

    // Single-head fast path: each example's Q/K/V is already contiguous
    // per head, so kernels write straight into the output — no staging.
    if heads == 1 {
        par_chunks_mut(&mut out[..prob.valid * per], per, |i, out_ex| {
            let (q, k, v) = data.example(prob, i);
            let mut pooled = pool.acquire();
            let (ws, wstats) = pooled.parts();
            kernel.run(q, k, v, n, dim, ws, out_ex, wstats);
        });
        pool.collect_stats(stats);
        return;
    }

    // General path: gather each head into contiguous [n, dh] slices,
    // solve every (example, head) as an independent work item, then
    // scatter head results back to model-dim layout.
    // No element of the staging buffer needs initialization — every chunk
    // row is overwritten by its kernel run; the zero fill-value below is
    // only resize's required argument (it memsets growth once per
    // high-water mark, never in steady state). Do not rely on zeroing.
    let hd = n * dh;
    headout.resize(prob.work_items() * hd, 0.0);
    par_chunks_mut(headout.as_mut_slice(), hd, |w, head_out| {
        let (i, h) = (w / heads, w % heads);
        let (q, k, v) = data.example(prob, i);
        let mut pooled = pool.acquire();
        let (ws, wstats) = pooled.parts();
        let mut qh = ws.take_f32("item.q", hd);
        let mut kh = ws.take_f32("item.k", hd);
        let mut vh = ws.take_f32("item.v", hd);
        gather_head(q, n, dim, dh, h, &mut qh);
        gather_head(k, n, dim, dh, h, &mut kh);
        gather_head(v, n, dim, dh, h, &mut vh);
        kernel.run(&qh, &kh, &vh, n, dh, ws, head_out, wstats);
        ws.give_f32("item.q", qh);
        ws.give_f32("item.k", kh);
        ws.give_f32("item.v", vh);
    });
    pool.collect_stats(stats);

    let staged: &[f32] = headout.as_slice();
    par_chunks_mut(&mut out[..prob.valid * per], per, |i, out_ex| {
        for h in 0..heads {
            let w = i * heads + h;
            scatter_head(&staged[w * hd..(w + 1) * hd], n, dim, dh, h, out_ex);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernels::dense::dense_attention_mh;
    use crate::kernels::mita::mita_attention_mh;

    #[test]
    fn registry_lookup_replace_and_names() {
        let cfg = MitaKernelConfig::default();
        let mut r = KernelRegistry::with_defaults(cfg);
        assert_eq!(
            r.names(),
            vec![
                OP_ATTN_MITA,
                OP_ATTN_DENSE,
                crate::decode::OP_ATTN_MITA_CAUSAL,
                crate::decode::OP_ATTN_DENSE_CAUSAL,
            ]
        );
        assert!(r.get(OP_ATTN_MITA).is_some());
        assert!(r.get("predict").is_none());
        assert!(r.resolve(OP_ATTN_MITA).is_ok());
        let miss = r.resolve("predict").unwrap_err();
        assert!(miss.contains(OP_ATTN_MITA) && miss.contains(OP_ATTN_DENSE), "{miss}");

        // Re-registering a name replaces in place (no duplicate entries).
        let custom = MitaKernelConfig { m: 2, k: 2, cap_factor: 1, block_q: 1 };
        r.register(Box::new(MitaKernel { cfg: custom }));
        assert_eq!(r.names().len(), 4);
    }

    #[test]
    fn problem_validation() {
        let p = AttnProblem::new(4, 3, 8, 16, QkvLayout::Fused);
        assert!(p.validate().is_err()); // 16 % 3 != 0
        let p = AttnProblem::new(4, 2, 8, 16, QkvLayout::Fused);
        assert!(p.validate().is_ok());
        assert_eq!(p.head_dim(), 8);
        assert_eq!(p.work_items(), 8);
        assert!(p.with_valid(5).validate().is_err()); // valid > batch
        assert!(p.with_valid(2).validate().is_ok());
    }

    #[test]
    fn stats_record_merge_reset() {
        let mut a = MitaStats::default();
        a.record(8, 2, &[5, 3]);
        a.record(8, 0, &[4, 4]);
        assert_eq!(a.calls, 2);
        assert_eq!(a.queries, 16);
        assert_eq!(a.overflow, 2);
        assert_eq!(a.expert_counts, vec![9, 7]);
        assert!((a.overflow_fraction() - 0.125).abs() < 1e-12);
        // Peak per-call skew: the [5, 3] call (5·2/8 = 1.25), not the
        // balanced-looking aggregate [9, 7].
        assert!((a.load_imbalance() - 1.25).abs() < 1e-12);

        let mut b = MitaStats::default();
        b.record(8, 1, &[2, 0]); // fully skewed call: 2·2/2 = 2.0
        assert!((b.load_imbalance() - 2.0).abs() < 1e-12);

        let mut m = MitaStats::default();
        m.merge(&a);
        m.merge(&b);
        assert_eq!(m.queries, 18);
        assert_eq!(m.expert_counts, vec![11, 7]);
        assert!((m.load_imbalance() - 2.0).abs() < 1e-12, "merge keeps the worst peak");
        m.reset();
        assert_eq!(m, MitaStats::default());
    }

    #[test]
    fn block_profiles_merge_element_wise() {
        let mut a = BlockProfile { attn_ns: 10, mlp_ns: 5, stats: MitaStats::default() };
        a.stats.record(8, 1, &[3, 5]);
        let mut b = BlockProfile { attn_ns: 7, mlp_ns: 2, stats: MitaStats::default() };
        b.stats.record(8, 0, &[4, 4]);

        let mut acc: Vec<BlockProfile> = Vec::new();
        merge_block_profiles(&mut acc, &[a.clone()]);
        assert_eq!(acc.len(), 1);
        merge_block_profiles(&mut acc, &[b.clone(), a.clone()]);
        assert_eq!(acc.len(), 2, "merging grows to the larger depth");
        assert_eq!(acc[0].attn_ns, 17);
        assert_eq!(acc[0].mlp_ns, 7);
        assert_eq!(acc[0].stats.queries, 16);
        assert_eq!(acc[0].stats.overflow, 1);
        assert_eq!(acc[1], a, "new tail entries copy the addend");
    }

    #[test]
    fn run_batched_matches_per_sequence_mh() {
        let (b, heads, n, dim) = (3usize, 2usize, 20usize, 8usize);
        let per = n * dim;
        let mut rng = Rng::new(17);
        let data: Vec<f32> = (0..b * 3 * per).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let cfg = MitaKernelConfig { m: 4, k: 8, cap_factor: 2, block_q: 4 };

        let prob = AttnProblem::new(b, heads, n, dim, QkvLayout::Fused);
        let view = QkvData::Fused(&data);
        let pool = WorkspacePool::new();
        let mut headout = Vec::new();
        let mut stats = MitaStats::default();
        let mut got = vec![0.0f32; b * per];
        run_batched(&MitaKernel { cfg }, &prob, &view, &pool, &mut headout, &mut got, &mut stats);

        let mut ws = Workspace::new();
        let mut want = vec![0.0f32; b * per];
        let mut ref_stats = MitaStats::default();
        for i in 0..b {
            let (q, k, v) = view.example(&prob, i);
            mita_attention_mh(
                q,
                k,
                v,
                n,
                heads,
                dim,
                &cfg,
                &mut ws,
                &mut want[i * per..(i + 1) * per],
                &mut ref_stats,
            );
        }
        assert_eq!(got, want, "batched decomposition must be bit-identical");
        assert_eq!(stats.calls, b * heads);
        assert_eq!(stats.queries, b * heads * n);
        assert_eq!(stats.queries, ref_stats.queries);
        assert_eq!(stats.overflow, ref_stats.overflow);

        // Dense kernel through the same executor.
        let mut got_d = vec![0.0f32; b * per];
        run_batched(&DenseKernel, &prob, &view, &pool, &mut headout, &mut got_d, &mut stats);
        let mut want_d = vec![0.0f32; b * per];
        for i in 0..b {
            let (q, k, v) = view.example(&prob, i);
            let out_ex = &mut want_d[i * per..(i + 1) * per];
            dense_attention_mh(q, k, v, n, heads, dim, &mut ws, out_ex);
        }
        assert_eq!(got_d, want_d);
    }

    #[test]
    fn run_batched_skips_padding_rows() {
        let (b, valid, heads, n, dim) = (4usize, 2usize, 2usize, 12usize, 8usize);
        let per = n * dim;
        let mut rng = Rng::new(23);
        let data: Vec<f32> = (0..b * 3 * per).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let prob = AttnProblem::new(b, heads, n, dim, QkvLayout::Fused).with_valid(valid);
        let view = QkvData::Fused(&data);
        let pool = WorkspacePool::new();
        let mut headout = Vec::new();
        let mut stats = MitaStats::default();
        let mut out = vec![f32::NAN; b * per]; // pads must be overwritten to 0
        let cfg = MitaKernelConfig { m: 3, k: 6, cap_factor: 2, block_q: 4 };
        let kernel = MitaKernel { cfg };
        run_batched(&kernel, &prob, &view, &pool, &mut headout, &mut out, &mut stats);

        assert!(out[..valid * per].iter().all(|x| x.is_finite()));
        assert!(out[valid * per..].iter().all(|&x| x == 0.0), "pad rows must stay zero");
        assert_eq!(stats.calls, valid * heads, "pad rows must never be computed");
        assert_eq!(stats.queries, valid * heads * n);
    }
}
