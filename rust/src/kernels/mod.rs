//! Native CPU execution kernels for MiTA and dense attention.
//!
//! The module is organized as a small execution stack:
//!
//! - [`simd`]: the runtime-dispatched SIMD lanes (scalar reference,
//!   portable autovectorized baseline, AVX2, NEON) behind one
//!   function-pointer table — every lane bit-identical by a fixed
//!   reduction order (see `docs/PERF.md`; override with `MITA_SIMD`).
//! - [`linalg`]: blocked row-major matmuls + softmax primitives, routed
//!   through the dispatched SIMD ops.
//! - [`workspace`]: the [`Workspace`] scratch arena (zero allocations in
//!   steady state) and the thread-safe [`WorkspacePool`] behind it.
//! - [`mita`] / [`dense`]: serial, allocation-free single-head kernels —
//!   the full MiTA forward (landmark pooling, landmark scores, top-k KV
//!   expert construction, argmax-routed dispatch with capacity packing,
//!   reusing `crate::mita::routing`, plus an exact overflow fallback) and
//!   the O(N²) dense baseline.
//! - [`api`]: the [`AttentionKernel`] trait, the name-keyed
//!   [`KernelRegistry`], the [`AttnProblem`] shape descriptor, and
//!   [`run_batched`] — the (example × head) work-item executor that owns
//!   all parallelism.
//! - [`par`]: scoped-thread parallel helpers (std-only rayon substitute)
//!   that schedule those work items.
//! - [`profile`]: the always-on op-level profiler — atomic `(ns, calls)`
//!   accumulators the kernel phases and decode loop report into,
//!   exported via `GET /v1/profile` and the `op_*_total` metric series.
//!
//! The [`crate::runtime::backend`] module exposes this stack behind the
//! same `Backend` interface as the PJRT artifact path.

pub mod api;
pub mod dense;
pub mod linalg;
pub mod mita;
pub mod par;
pub mod profile;
pub mod simd;
pub mod workspace;

pub use api::{
    merge_block_profiles, run_batched, AttentionKernel, AttnProblem, BlockProfile, DenseKernel,
    KernelRegistry, MitaKernel, MitaStats, OP_ATTN_DENSE, OP_ATTN_MITA, QkvData, QkvLayout,
};
pub use dense::{dense_attention, dense_attention_mh};
pub use mita::{mita_attention, mita_attention_mh, MitaKernelConfig};
pub use workspace::{PooledWorkspace, Workspace, WorkspacePool};
