//! Native CPU execution kernels for MiTA and dense attention.
//!
//! Until now the Rust side could only *execute* attention through AOT PJRT
//! artifacts; this module implements the forward pass directly on the host
//! so the serving loop, benchmarks, and tests run on a plain machine with
//! no Python, JAX, or PJRT closure installed:
//!
//! - [`linalg`]: blocked row-major matmuls + softmax primitives.
//! - [`par`]: scoped-thread parallel helpers (std-only rayon substitute).
//! - [`dense`]: O(N²) softmax attention — the correctness baseline.
//! - [`mita`]: the full MiTA forward — landmark pooling, landmark scores,
//!   top-k KV expert construction, argmax-routed dispatch with capacity
//!   packing (reusing `crate::mita::routing`), per-expert attention, and
//!   output scatter.
//!
//! The [`crate::runtime::backend`] module exposes these behind the same
//! `Backend` interface as the PJRT artifact path.

pub mod dense;
pub mod linalg;
pub mod mita;
pub mod par;

pub use dense::{dense_attention, dense_attention_mh};
pub use mita::{mita_attention, mita_attention_mh, MitaKernelConfig, MitaStats};
