//! The single runtime dispatch point: one [`SimdOps`] function-pointer
//! table, selected once (on first use) from the `MITA_SIMD` environment
//! variable and CPU feature detection, then read lock-free on every hot
//! call.
//!
//! The active table lives in an `AtomicPtr` rather than a `OnceLock` so
//! the bit-parity tests can flip lanes *in one process*
//! ([`set_lane`]) and compare whole-model outputs across them; normal
//! operation initializes exactly once and never changes lanes again.

use std::sync::atomic::{AtomicPtr, Ordering};

/// The dispatch table: every dispatched primitive as a plain function
/// pointer. All lanes implementing this table return **bit-identical**
/// results (the canonical reduction spec in the module docs); selection
/// is purely a throughput decision.
#[derive(Debug)]
pub struct SimdOps {
    /// Lane name as reported in `/v1/metrics`, `native-check`, and the
    /// bench JSON (`"scalar" | "portable" | "avx2" | "neon"`).
    pub name: &'static str,
    /// `Σ x[i]·y[i]` (canonical tree reduction).
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `Σ x[i]` (canonical tree reduction).
    pub sum: fn(&[f32]) -> f32,
    /// `max x[i]` over non-NaN inputs (`NEG_INFINITY` when empty).
    pub max: fn(&[f32]) -> f32,
    /// `Σ (x[i] − mean)²` (canonical tree reduction).
    pub sq_dev_sum: fn(&[f32], f32) -> f32,
    /// `y[i] += alpha · x[i]`.
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// `x[i] *= s`.
    pub scale: fn(&mut [f32], f32),
    /// `out[i] = ((x[i] − mean) · inv) · g[i] + b[i]`.
    pub norm_affine: fn(&[f32], f32, f32, &[f32], &[f32], &mut [f32]),
    /// GELU (tanh approximation) in place — shared scalar libm code on
    /// every lane (no bit-reproducible vector `tanh` exists).
    pub gelu: fn(&mut [f32]),
    /// `out[j] = src[offset + j · stride]` — the top-k column gather.
    pub gather_stride: fn(&[f32], usize, usize, &mut [f32]),
}

/// A selectable SIMD lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Spelled-out reference implementation of the canonical spec.
    Scalar,
    /// Autovectorization-friendly arch-independent implementation.
    Portable,
    /// AVX2 intrinsics (x86_64 with runtime `avx2` detection).
    Avx2,
    /// NEON intrinsics (aarch64; mandatory feature, always available).
    Neon,
}

impl Lane {
    /// Every lane, in preference-independent listing order.
    pub const ALL: [Lane; 4] = [Lane::Scalar, Lane::Portable, Lane::Avx2, Lane::Neon];

    /// The lane's `MITA_SIMD` spelling / telemetry name.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Portable => "portable",
            Lane::Avx2 => "avx2",
            Lane::Neon => "neon",
        }
    }
}

/// Null until first use; then always a `&'static SimdOps` cast to a raw
/// pointer, so loads after initialization are branch-plus-deref cheap.
static ACTIVE: AtomicPtr<SimdOps> = AtomicPtr::new(std::ptr::null_mut());

/// The active dispatch table, initializing from `MITA_SIMD` (default
/// `auto`) on first call. Reading it is lock-free; hot loops may also
/// hoist individual function pointers out of the table.
#[inline]
pub fn ops() -> &'static SimdOps {
    let p = ACTIVE.load(Ordering::Acquire);
    if p.is_null() {
        init_from_env()
    } else {
        // SAFETY: non-null values stored in ACTIVE are always &'static.
        unsafe { &*p }
    }
}

/// The name of the lane currently answering [`ops`] — the value surfaced
/// in `/v1/metrics`, `native-check`, and the bench JSON.
pub fn active_lane() -> &'static str {
    ops().name
}

/// Force a lane, returning its table. **Test hook**: the bit-parity
/// suite uses this to compare whole-model outputs across lanes in one
/// process. Panics if the lane is unavailable on this host. Not for
/// production paths — lanes are bit-identical, so there is never a
/// correctness reason to switch at runtime.
pub fn set_lane(lane: Lane) -> &'static SimdOps {
    let t = lane_table(lane)
        .unwrap_or_else(|| panic!("SIMD lane {:?} is not available on this host", lane));
    install(t);
    t
}

/// The dispatch table for `lane`, or `None` when this build/CPU cannot
/// run it. Lets tests exercise a lane's functions directly without
/// touching the global dispatch state.
pub fn lane_table(lane: Lane) -> Option<&'static SimdOps> {
    match lane {
        Lane::Scalar => Some(&super::scalar::OPS),
        Lane::Portable => Some(&super::portable::OPS),
        Lane::Avx2 => avx2_table(),
        Lane::Neon => neon_table(),
    }
}

/// Every lane the current build + CPU can actually run.
pub fn available_lanes() -> Vec<Lane> {
    Lane::ALL.iter().copied().filter(|&l| lane_table(l).is_some()).collect()
}

fn install(t: &'static SimdOps) {
    ACTIVE.store(t as *const SimdOps as *mut SimdOps, Ordering::Release);
}

/// Resolve `MITA_SIMD` (unset ⇒ `auto`). Forcing an unavailable lane or
/// an unknown spelling panics — a silent fallback would make every
/// recorded bench/telemetry lane name a lie.
fn init_from_env() -> &'static SimdOps {
    let spec = std::env::var("MITA_SIMD").unwrap_or_else(|_| "auto".to_string());
    let lane = match spec.as_str() {
        "auto" | "" => auto_lane(),
        "scalar" => Lane::Scalar,
        "portable" => Lane::Portable,
        "avx2" => Lane::Avx2,
        "neon" => Lane::Neon,
        other => panic!(
            "MITA_SIMD={other:?} is not one of scalar|portable|avx2|neon|auto"
        ),
    };
    let t = lane_table(lane).unwrap_or_else(|| {
        panic!(
            "MITA_SIMD={spec:?} selects lane {:?}, which this host cannot run \
             (available: {})",
            lane,
            available_lanes().iter().map(|l| l.name()).collect::<Vec<_>>().join(", ")
        )
    });
    install(t);
    t
}

/// The best lane for this host: a hand-written arch lane when the CPU
/// has one, otherwise the portable baseline.
pub fn auto_lane() -> Lane {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Lane::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Lane::Neon;
    }
    #[allow(unreachable_code)]
    Lane::Portable
}

#[cfg(target_arch = "x86_64")]
fn avx2_table() -> Option<&'static SimdOps> {
    if std::arch::is_x86_feature_detected!("avx2") {
        Some(&super::x86::OPS)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_table() -> Option<&'static SimdOps> {
    None
}

#[cfg(target_arch = "aarch64")]
fn neon_table() -> Option<&'static SimdOps> {
    Some(&super::neon::OPS)
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_table() -> Option<&'static SimdOps> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_independent_lanes_always_exist() {
        assert!(lane_table(Lane::Scalar).is_some());
        assert!(lane_table(Lane::Portable).is_some());
        let avail = available_lanes();
        assert!(avail.contains(&Lane::Scalar) && avail.contains(&Lane::Portable));
        assert!(avail.contains(&auto_lane()), "auto must resolve to an available lane");
    }

    #[test]
    fn ops_resolves_and_reports_a_known_lane() {
        let name = active_lane();
        assert!(
            Lane::ALL.iter().any(|l| l.name() == name),
            "active lane {name:?} is not a known lane name"
        );
    }

    #[test]
    fn scalar_and_portable_are_bit_identical_on_odd_lengths() {
        // The cross-arch pair that exists everywhere; the arch lanes get
        // the same treatment (plus forced-lane runs) in
        // tests/simd_parity.rs.
        let s = lane_table(Lane::Scalar).unwrap();
        let p = lane_table(Lane::Portable).unwrap();
        for n in [0usize, 1, 7, 8, 9, 31, 1007] {
            let x: Vec<f32> = (0..n).map(|i| ((i * 37 % 19) as f32) * 0.37 - 3.0).collect();
            let y: Vec<f32> = (0..n).map(|i| ((i * 53 % 29) as f32) * 0.21 - 2.0).collect();
            assert_eq!(((s.dot)(&x, &y)).to_bits(), ((p.dot)(&x, &y)).to_bits(), "dot n={n}");
            assert_eq!(((s.sum)(&x)).to_bits(), ((p.sum)(&x)).to_bits(), "sum n={n}");
            if n > 0 {
                assert_eq!(((s.max)(&x)).to_bits(), ((p.max)(&x)).to_bits(), "max n={n}");
            }
            assert_eq!(
                ((s.sq_dev_sum)(&x, 0.25)).to_bits(),
                ((p.sq_dev_sum)(&x, 0.25)).to_bits(),
                "sq_dev_sum n={n}"
            );
        }
    }
}
