//! Runtime-dispatched SIMD kernels for the native hot loops.
//!
//! Every dense primitive the attention kernels, the transformer, and the
//! training backward run in their inner loops — dot products, axpy,
//! scaling, row reductions, LayerNorm normalization, GELU, the top-k
//! column gather — routes through one function-pointer table
//! ([`SimdOps`]) selected **once** at startup by [`dispatch`]:
//!
//! - [`scalar`]: the spelled-out reference implementation of the
//!   canonical reduction spec below. What you read here is the contract.
//! - [`portable`]: the same spec written over `chunks_exact` windows, the
//!   shape LLVM autovectorizes on any target. This is the default answer
//!   on machines without a hand-written lane.
//! - [`x86`] (x86_64 only): AVX2 `core::arch` intrinsics, installed only
//!   after `is_x86_feature_detected!("avx2")` succeeds at runtime.
//! - [`neon`] (aarch64 only): NEON intrinsics (mandatory on aarch64).
//!
//! ## The determinism contract
//!
//! This codebase pins results bit-for-bit across thread counts, steady
//! vs. fresh workspaces, and forward vs. backward recomputation — so a
//! SIMD lane is only admissible if it returns **bit-identical** results
//! to every other lane. That is achieved by fixing one canonical
//! reduction order, with [`W`] = 8 arch-independent accumulator lanes:
//!
//! 1. Full 8-wide chunks accumulate element-wise:
//!    `acc[j] += x[8·i + j] · y[8·i + j]` (j = 0..8).
//! 2. The 8 accumulators reduce through a fixed tree
//!    ([`tree8_add`] / [`tree8_max`]):
//!    `s_j = acc[j] + acc[j+4]`, `t_j = s_j + s_{j+2}`, `r = t_0 + t_1`.
//! 3. The `len % 8` tail then folds **sequentially** into `r`.
//!
//! The AVX2 lane realizes exactly this tree with
//! `_mm_add_ps(lo128, hi128)` → `movehl` → `shuffle`+`add_ss`; the NEON
//! lane with two `float32x4` accumulators → `vaddq` → low/high `vadd` →
//! lane 0 + lane 1. Three consequences worth knowing:
//!
//! - **No FMA.** `_mm256_fmadd_ps` / `vfmaq_f32` round once where
//!   mul-then-add rounds twice; a fused lane could never be bit-identical
//!   to the scalar spec, so every lane uses separate multiply and add
//!   (and Rust never contracts `a * b + c` on its own).
//! - **libm stays scalar.** `exp` (softmax) and `tanh` (GELU) have no
//!   bit-reproducible vector form, so all lanes share the scalar
//!   transcendental loops; only the max/scale/reduction parts of softmax
//!   and LayerNorm are dispatched. Element-wise ops (axpy, scale,
//!   normalize-affine) have no cross-lane reduction at all, so their
//!   vector forms are trivially bit-identical.
//! - **Reductions assume non-NaN inputs.** `_mm256_max_ps` and
//!   `f32::max` agree on every non-NaN input (a ±0.0 disagreement cannot
//!   leak through `v - max`); feeding NaN logits into softmax was
//!   already undefined behavior-adjacent before this layer existed.
//!
//! The canonical order **replaces** the old `iter().sum()` sequential
//! order as the single source of truth — existing parity tests keep
//! their tolerances and pass against it unchanged; the new
//! `tests/simd_parity.rs` additionally proves bit-equality across every
//! lane the host can run.
//!
//! Lane selection is overridable with `MITA_SIMD=scalar|portable|avx2|
//! neon|auto` (default `auto`); forcing a lane the host cannot run
//! panics loudly instead of silently falling back. See `docs/PERF.md`.

pub mod dispatch;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod portable;
pub mod scalar;
#[cfg(target_arch = "x86_64")]
pub mod x86;

pub use dispatch::{active_lane, available_lanes, lane_table, ops, set_lane, Lane, SimdOps};

/// Canonical accumulator width: 8 lanes on every arch (one AVX2 vector,
/// two NEON vectors, an 8-element array in scalar/portable code).
pub const W: usize = 8;

/// The fixed add-reduction tree over the 8 canonical accumulators.
/// Matches AVX2's 128-bit fold (`lo+hi` → `movehl` → `shuffle`) and
/// NEON's two-register fold exactly — change nothing here without
/// changing every lane in lockstep.
#[inline(always)]
pub(crate) fn tree8_add(a: [f32; W]) -> f32 {
    let s0 = a[0] + a[4];
    let s1 = a[1] + a[5];
    let s2 = a[2] + a[6];
    let s3 = a[3] + a[7];
    let t0 = s0 + s2;
    let t1 = s1 + s3;
    t0 + t1
}

/// [`tree8_add`]'s max-reduction twin (same shape, `max` for `+`).
#[inline(always)]
pub(crate) fn tree8_max(a: [f32; W]) -> f32 {
    let s0 = a[0].max(a[4]);
    let s1 = a[1].max(a[5]);
    let s2 = a[2].max(a[6]);
    let s3 = a[3].max(a[7]);
    let t0 = s0.max(s2);
    let t1 = s1.max(s3);
    t0.max(t1)
}
