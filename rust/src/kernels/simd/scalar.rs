//! The scalar reference lane: a spelled-out, index-by-index rendering of
//! the canonical reduction spec (see the module docs of
//! [`crate::kernels::simd`]). Every other lane must match this one
//! bit-for-bit; when in doubt about what a primitive is defined to
//! compute, read it here.

// Indexed chunk/tail loops are the point of this file — they spell out
// the canonical order. Iterator rewrites would obscure the spec.
#![allow(clippy::needless_range_loop)]

use super::dispatch::SimdOps;
use super::{tree8_add, tree8_max, W};

/// The scalar lane's dispatch table.
pub static OPS: SimdOps = SimdOps {
    name: "scalar",
    dot,
    sum,
    max,
    sq_dev_sum,
    axpy,
    scale,
    norm_affine,
    gelu,
    gather_stride,
};

/// Canonical dot product: 8 accumulators over full chunks, fixed tree
/// reduce, then a sequential tail.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % W;
    let mut acc = [0.0f32; W];
    let mut i = 0;
    while i < split {
        for j in 0..W {
            acc[j] += x[i + j] * y[i + j];
        }
        i += W;
    }
    let mut r = tree8_add(acc);
    for j in split..x.len() {
        r += x[j] * y[j];
    }
    r
}

/// Canonical sum (same chunk/tree/tail order as [`dot`]).
pub fn sum(x: &[f32]) -> f32 {
    let split = x.len() - x.len() % W;
    let mut acc = [0.0f32; W];
    let mut i = 0;
    while i < split {
        for j in 0..W {
            acc[j] += x[i + j];
        }
        i += W;
    }
    let mut r = tree8_add(acc);
    for j in split..x.len() {
        r += x[j];
    }
    r
}

/// Canonical max fold. Inputs must be non-NaN (see module docs); empty
/// slices return `NEG_INFINITY`, matching the old `fold` identity.
pub fn max(x: &[f32]) -> f32 {
    let split = x.len() - x.len() % W;
    let mut acc = [f32::NEG_INFINITY; W];
    let mut i = 0;
    while i < split {
        for j in 0..W {
            acc[j] = acc[j].max(x[i + j]);
        }
        i += W;
    }
    let mut r = tree8_max(acc);
    for j in split..x.len() {
        r = r.max(x[j]);
    }
    r
}

/// Canonical `Σ (x[i] − mean)²` — the LayerNorm variance numerator.
pub fn sq_dev_sum(x: &[f32], mean: f32) -> f32 {
    let split = x.len() - x.len() % W;
    let mut acc = [0.0f32; W];
    let mut i = 0;
    while i < split {
        for j in 0..W {
            let d = x[i + j] - mean;
            acc[j] += d * d;
        }
        i += W;
    }
    let mut r = tree8_add(acc);
    for j in split..x.len() {
        let d = x[j] - mean;
        r += d * d;
    }
    r
}

/// `y[i] += alpha · x[i]`. Element-wise — no reduction, so any lane's
/// vectorization of this exact expression is bit-identical.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x[i] *= s`, element-wise.
pub fn scale(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// LayerNorm's normalize-affine: `out[i] = ((x[i] − mean) · inv) · g[i]
/// + b[i]`, element-wise in exactly that association order.
pub fn norm_affine(x: &[f32], mean: f32, inv: f32, g: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), b.len());
    for (((o, &v), &gc), &bc) in out.iter_mut().zip(x).zip(g).zip(b) {
        *o = (v - mean) * inv * gc + bc;
    }
}

/// GELU (tanh approximation), in place. `tanh` is libm — there is no
/// bit-reproducible vector form — so **every** lane's table points at
/// this one scalar implementation. Constants are mirrored by
/// [`crate::train::backward::gelu_backward`].
pub fn gelu(x: &mut [f32]) {
    const C: f32 = 0.797_884_6; // sqrt(2/π)
    const A: f32 = 0.044_715;
    for v in x.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + A * u * u * u)).tanh());
    }
}

/// Strided gather: `out[j] = src[offset + j · stride]` — the top-k scan's
/// column copy. A pure data movement, so lanes are trivially identical.
pub fn gather_stride(src: &[f32], offset: usize, stride: usize, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        *o = src[offset + j * stride];
    }
}
