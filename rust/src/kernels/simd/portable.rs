//! The portable lane: the canonical spec written over `chunks_exact`
//! windows and fixed-size array accumulators — the shape LLVM reliably
//! autovectorizes on every target, without any `core::arch` intrinsics.
//! Bit-identical to [`super::scalar`] by construction (same chunking,
//! same [`tree8_add`] reduction, same sequential tail); this lane is the
//! `auto` answer on hosts with no hand-written variant.

// The fixed-width `for j in 0..W` window bodies mirror the canonical
// spec; iterator rewrites would obscure the chunk/tail structure.
#![allow(clippy::needless_range_loop)]

use super::dispatch::SimdOps;
use super::{tree8_add, tree8_max, W};

/// The portable lane's dispatch table.
pub static OPS: SimdOps = SimdOps {
    name: "portable",
    dot,
    sum,
    max,
    sq_dev_sum,
    axpy,
    scale,
    norm_affine,
    gelu: super::scalar::gelu,
    gather_stride: super::scalar::gather_stride,
};

/// Canonical dot product over 8-wide windows.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; W];
    let xc = x.chunks_exact(W);
    let yc = y.chunks_exact(W);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (xs, ys) in xc.zip(yc) {
        for j in 0..W {
            acc[j] += xs[j] * ys[j];
        }
    }
    let mut r = tree8_add(acc);
    for (a, b) in xr.iter().zip(yr) {
        r += a * b;
    }
    r
}

/// Canonical sum over 8-wide windows.
pub fn sum(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; W];
    let xc = x.chunks_exact(W);
    let xr = xc.remainder();
    for xs in xc {
        for j in 0..W {
            acc[j] += xs[j];
        }
    }
    let mut r = tree8_add(acc);
    for v in xr {
        r += v;
    }
    r
}

/// Canonical max fold over 8-wide windows (non-NaN inputs).
pub fn max(x: &[f32]) -> f32 {
    let mut acc = [f32::NEG_INFINITY; W];
    let xc = x.chunks_exact(W);
    let xr = xc.remainder();
    for xs in xc {
        for j in 0..W {
            acc[j] = acc[j].max(xs[j]);
        }
    }
    let mut r = tree8_max(acc);
    for &v in xr {
        r = r.max(v);
    }
    r
}

/// Canonical `Σ (x[i] − mean)²` over 8-wide windows.
pub fn sq_dev_sum(x: &[f32], mean: f32) -> f32 {
    let mut acc = [0.0f32; W];
    let xc = x.chunks_exact(W);
    let xr = xc.remainder();
    for xs in xc {
        for j in 0..W {
            let d = xs[j] - mean;
            acc[j] += d * d;
        }
    }
    let mut r = tree8_add(acc);
    for &v in xr {
        let d = v - mean;
        r += d * d;
    }
    r
}

/// `y += alpha · x` over 8-wide windows (element-wise, bit-identical to
/// the scalar loop regardless of vectorization).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let yc = y.chunks_exact_mut(W);
    let xc = x.chunks_exact(W);
    let xr = xc.remainder();
    let mut tail_start = 0;
    for (ys, xs) in yc.zip(xc) {
        for j in 0..W {
            ys[j] += alpha * xs[j];
        }
        tail_start += W;
    }
    for (yi, xi) in y[tail_start..].iter_mut().zip(xr) {
        *yi += alpha * xi;
    }
}

/// `x *= s` over 8-wide windows.
pub fn scale(x: &mut [f32], s: f32) {
    let xc = x.chunks_exact_mut(W);
    let mut tail_start = 0;
    for xs in xc {
        for j in 0..W {
            xs[j] *= s;
        }
        tail_start += W;
    }
    for v in x[tail_start..].iter_mut() {
        *v *= s;
    }
}

/// Normalize-affine over 8-wide windows (same association order as the
/// scalar lane: `((x − mean) · inv) · g + b`).
pub fn norm_affine(x: &[f32], mean: f32, inv: f32, g: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), b.len());
    let oc = out.chunks_exact_mut(W);
    let xc = x.chunks_exact(W);
    let gc = g.chunks_exact(W);
    let bc = b.chunks_exact(W);
    let mut tail = 0;
    for (((os, xs), gs), bs) in oc.zip(xc).zip(gc).zip(bc) {
        for j in 0..W {
            os[j] = (xs[j] - mean) * inv * gs[j] + bs[j];
        }
        tail += W;
    }
    for i in tail..x.len() {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}
