//! The NEON lane (aarch64): two `float32x4` registers carry the 8
//! canonical accumulators (lanes 0–3 and 4–7 of the spec).
//!
//! Bit-parity rules (see the module docs): multiply-then-add only —
//! never `vfmaq_f32` (single rounding) and never `vaddvq_f32` (a
//! different reduction tree). [`tree_add`] / [`tree_max`] realize the
//! canonical tree exactly: `acc0 ⊕ acc1` gives `[a0⊕a4 … a3⊕a7]`, the
//! low/high 64-bit halves fold lanes 2,3 onto 0,1, and the final scalar
//! op folds lane 1 onto 0. NEON is mandatory on aarch64, so this lane
//! needs no runtime detection.

// Indexed tail loops keep the sequential-tail spec visible next to the
// intrinsics; iterator rewrites would obscure it.
#![allow(clippy::needless_range_loop)]

use core::arch::aarch64::*;

use super::dispatch::SimdOps;

/// The NEON lane's dispatch table.
pub static OPS: SimdOps = SimdOps {
    name: "neon",
    dot,
    sum,
    max,
    sq_dev_sum,
    axpy,
    scale,
    norm_affine,
    gelu: super::scalar::gelu,
    gather_stride: super::scalar::gather_stride,
};

/// Canonical add-tree over the two accumulator registers.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn tree_add(a0: float32x4_t, a1: float32x4_t) -> f32 {
    let s = vaddq_f32(a0, a1);
    let t = vadd_f32(vget_low_f32(s), vget_high_f32(s));
    vget_lane_f32::<0>(t) + vget_lane_f32::<1>(t)
}

/// Canonical max-tree over the two accumulator registers (non-NaN).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn tree_max(a0: float32x4_t, a1: float32x4_t) -> f32 {
    let s = vmaxq_f32(a0, a1);
    let t = vmax_f32(vget_low_f32(s), vget_high_f32(s));
    vget_lane_f32::<0>(t).max(vget_lane_f32::<1>(t))
}

pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { dot_neon(x, y) }
}

#[target_feature(enable = "neon")]
unsafe fn dot_neon(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let (xp, yp) = (x.as_ptr(), y.as_ptr());
    let mut a0 = vdupq_n_f32(0.0);
    let mut a1 = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let (px, py) = (xp.add(i * 8), yp.add(i * 8));
        a0 = vaddq_f32(a0, vmulq_f32(vld1q_f32(px), vld1q_f32(py)));
        a1 = vaddq_f32(a1, vmulq_f32(vld1q_f32(px.add(4)), vld1q_f32(py.add(4))));
    }
    let mut r = tree_add(a0, a1);
    for i in chunks * 8..n {
        r += x[i] * y[i];
    }
    r
}

pub fn sum(x: &[f32]) -> f32 {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { sum_neon(x) }
}

#[target_feature(enable = "neon")]
unsafe fn sum_neon(x: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let mut a0 = vdupq_n_f32(0.0);
    let mut a1 = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let p = xp.add(i * 8);
        a0 = vaddq_f32(a0, vld1q_f32(p));
        a1 = vaddq_f32(a1, vld1q_f32(p.add(4)));
    }
    let mut r = tree_add(a0, a1);
    for i in chunks * 8..n {
        r += x[i];
    }
    r
}

pub fn max(x: &[f32]) -> f32 {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { max_neon(x) }
}

#[target_feature(enable = "neon")]
unsafe fn max_neon(x: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let mut a0 = vdupq_n_f32(f32::NEG_INFINITY);
    let mut a1 = vdupq_n_f32(f32::NEG_INFINITY);
    for i in 0..chunks {
        let p = xp.add(i * 8);
        a0 = vmaxq_f32(a0, vld1q_f32(p));
        a1 = vmaxq_f32(a1, vld1q_f32(p.add(4)));
    }
    let mut r = tree_max(a0, a1);
    for i in chunks * 8..n {
        r = r.max(x[i]);
    }
    r
}

pub fn sq_dev_sum(x: &[f32], mean: f32) -> f32 {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { sq_dev_sum_neon(x, mean) }
}

#[target_feature(enable = "neon")]
unsafe fn sq_dev_sum_neon(x: &[f32], mean: f32) -> f32 {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let vm = vdupq_n_f32(mean);
    let mut a0 = vdupq_n_f32(0.0);
    let mut a1 = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let p = xp.add(i * 8);
        let d0 = vsubq_f32(vld1q_f32(p), vm);
        let d1 = vsubq_f32(vld1q_f32(p.add(4)), vm);
        a0 = vaddq_f32(a0, vmulq_f32(d0, d0));
        a1 = vaddq_f32(a1, vmulq_f32(d1, d1));
    }
    let mut r = tree_add(a0, a1);
    for i in chunks * 8..n {
        let d = x[i] - mean;
        r += d * d;
    }
    r
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { axpy_neon(alpha, x, y) }
}

#[target_feature(enable = "neon")]
unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let va = vdupq_n_f32(alpha);
    for i in 0..chunks {
        let p = yp.add(i * 4);
        vst1q_f32(p, vaddq_f32(vld1q_f32(p), vmulq_f32(va, vld1q_f32(xp.add(i * 4)))));
    }
    for i in chunks * 4..n {
        y[i] += alpha * x[i];
    }
}

pub fn scale(x: &mut [f32], s: f32) {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { scale_neon(x, s) }
}

#[target_feature(enable = "neon")]
unsafe fn scale_neon(x: &mut [f32], s: f32) {
    let n = x.len();
    let chunks = n / 4;
    let xp = x.as_mut_ptr();
    let vs = vdupq_n_f32(s);
    for i in 0..chunks {
        let p = xp.add(i * 4);
        vst1q_f32(p, vmulq_f32(vld1q_f32(p), vs));
    }
    for v in x[chunks * 4..].iter_mut() {
        *v *= s;
    }
}

pub fn norm_affine(x: &[f32], mean: f32, inv: f32, g: &[f32], b: &[f32], out: &mut [f32]) {
    // SAFETY: NEON is a mandatory aarch64 feature.
    unsafe { norm_affine_neon(x, mean, inv, g, b, out) }
}

#[target_feature(enable = "neon")]
unsafe fn norm_affine_neon(x: &[f32], mean: f32, inv: f32, g: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), b.len());
    let n = x.len();
    let chunks = n / 4;
    let (xp, gp, bp) = (x.as_ptr(), g.as_ptr(), b.as_ptr());
    let op = out.as_mut_ptr();
    let vm = vdupq_n_f32(mean);
    let vi = vdupq_n_f32(inv);
    for i in 0..chunks {
        let xhat = vmulq_f32(vsubq_f32(vld1q_f32(xp.add(i * 4)), vm), vi);
        let scaled = vmulq_f32(xhat, vld1q_f32(gp.add(i * 4)));
        vst1q_f32(op.add(i * 4), vaddq_f32(scaled, vld1q_f32(bp.add(i * 4))));
    }
    for i in chunks * 4..n {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}
