//! The AVX2 lane (x86_64): `core::arch` intrinsics realizing the
//! canonical 8-accumulator spec with one 256-bit register.
//!
//! Bit-parity rules this lane obeys (see the module docs):
//!
//! - **Mul-then-add only** — never `_mm256_fmadd_ps`. FMA's single
//!   rounding would diverge from the scalar spec's two roundings.
//! - The horizontal reductions ([`hadd_tree`] / [`hmax_tree`]) realize
//!   exactly the canonical tree: `lo128 ⊕ hi128` gives
//!   `[a0⊕a4, a1⊕a5, a2⊕a6, a3⊕a7]`, `movehl` folds lanes 2,3 onto
//!   0,1, and the final `shuffle` + scalar op folds lane 1 onto 0.
//! - Tails fold sequentially *after* the tree, like every other lane.
//!
//! Safety: every public function here is a safe wrapper whose only
//! caller contract is that this table is installed exclusively by
//! [`super::dispatch`] after `is_x86_feature_detected!("avx2")` has
//! succeeded on the running CPU.

// Indexed tail loops keep the sequential-tail spec visible next to the
// intrinsics; iterator rewrites would obscure it.
#![allow(clippy::needless_range_loop)]

use core::arch::x86_64::*;

use super::dispatch::SimdOps;

/// The AVX2 lane's dispatch table (installed only after runtime feature
/// detection).
pub static OPS: SimdOps = SimdOps {
    name: "avx2",
    dot,
    sum,
    max,
    sq_dev_sum,
    axpy,
    scale,
    norm_affine,
    gelu: super::scalar::gelu,
    gather_stride,
};

/// Canonical add-tree over one 256-bit accumulator.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hadd_tree(v: __m256) -> f32 {
    let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
    let t = _mm_add_ps(s, _mm_movehl_ps(s, s));
    _mm_cvtss_f32(_mm_add_ss(t, _mm_shuffle_ps(t, t, 1)))
}

/// Canonical max-tree over one 256-bit accumulator.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hmax_tree(v: __m256) -> f32 {
    let s = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
    let t = _mm_max_ps(s, _mm_movehl_ps(s, s));
    _mm_cvtss_f32(_mm_max_ss(t, _mm_shuffle_ps(t, t, 1)))
}

pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { dot_avx2(x, y) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let (xp, yp) = (x.as_ptr(), y.as_ptr());
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let xv = _mm256_loadu_ps(xp.add(i * 8));
        let yv = _mm256_loadu_ps(yp.add(i * 8));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
    }
    let mut r = hadd_tree(acc);
    for i in chunks * 8..n {
        r += x[i] * y[i];
    }
    r
}

pub fn sum(x: &[f32]) -> f32 {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { sum_avx2(x) }
}

#[target_feature(enable = "avx2")]
unsafe fn sum_avx2(x: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        acc = _mm256_add_ps(acc, _mm256_loadu_ps(xp.add(i * 8)));
    }
    let mut r = hadd_tree(acc);
    for i in chunks * 8..n {
        r += x[i];
    }
    r
}

pub fn max(x: &[f32]) -> f32 {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { max_avx2(x) }
}

#[target_feature(enable = "avx2")]
unsafe fn max_avx2(x: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
    for i in 0..chunks {
        acc = _mm256_max_ps(acc, _mm256_loadu_ps(xp.add(i * 8)));
    }
    let mut r = hmax_tree(acc);
    for i in chunks * 8..n {
        r = r.max(x[i]);
    }
    r
}

pub fn sq_dev_sum(x: &[f32], mean: f32) -> f32 {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { sq_dev_sum_avx2(x, mean) }
}

#[target_feature(enable = "avx2")]
unsafe fn sq_dev_sum_avx2(x: &[f32], mean: f32) -> f32 {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let vm = _mm256_set1_ps(mean);
    let mut acc = _mm256_setzero_ps();
    for i in 0..chunks {
        let d = _mm256_sub_ps(_mm256_loadu_ps(xp.add(i * 8)), vm);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
    }
    let mut r = hadd_tree(acc);
    for i in chunks * 8..n {
        let d = x[i] - mean;
        r += d * d;
    }
    r
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { axpy_avx2(alpha, x, y) }
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let va = _mm256_set1_ps(alpha);
    for i in 0..chunks {
        let xv = _mm256_loadu_ps(xp.add(i * 8));
        let yv = _mm256_loadu_ps(yp.add(i * 8));
        _mm256_storeu_ps(yp.add(i * 8), _mm256_add_ps(yv, _mm256_mul_ps(va, xv)));
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

pub fn scale(x: &mut [f32], s: f32) {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { scale_avx2(x, s) }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_avx2(x: &mut [f32], s: f32) {
    let n = x.len();
    let chunks = n / 8;
    let xp = x.as_mut_ptr();
    let vs = _mm256_set1_ps(s);
    for i in 0..chunks {
        _mm256_storeu_ps(xp.add(i * 8), _mm256_mul_ps(_mm256_loadu_ps(xp.add(i * 8)), vs));
    }
    for v in x[chunks * 8..].iter_mut() {
        *v *= s;
    }
}

pub fn norm_affine(x: &[f32], mean: f32, inv: f32, g: &[f32], b: &[f32], out: &mut [f32]) {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { norm_affine_avx2(x, mean, inv, g, b, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn norm_affine_avx2(x: &[f32], mean: f32, inv: f32, g: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), b.len());
    let n = x.len();
    let chunks = n / 8;
    let (xp, gp, bp) = (x.as_ptr(), g.as_ptr(), b.as_ptr());
    let op = out.as_mut_ptr();
    let vm = _mm256_set1_ps(mean);
    let vi = _mm256_set1_ps(inv);
    for i in 0..chunks {
        let xhat = _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(xp.add(i * 8)), vm), vi);
        let scaled = _mm256_mul_ps(xhat, _mm256_loadu_ps(gp.add(i * 8)));
        _mm256_storeu_ps(op.add(i * 8), _mm256_add_ps(scaled, _mm256_loadu_ps(bp.add(i * 8))));
    }
    for i in chunks * 8..n {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}

pub fn gather_stride(src: &[f32], offset: usize, stride: usize, out: &mut [f32]) {
    // SAFETY: table installed only after AVX2 runtime detection.
    unsafe { gather_stride_avx2(src, offset, stride, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn gather_stride_avx2(src: &[f32], offset: usize, stride: usize, out: &mut [f32]) {
    let n = out.len();
    if n == 0 {
        return;
    }
    let last = offset + (n - 1) * stride;
    debug_assert!(last < src.len(), "gather_stride reads past src");
    let chunks = n / 8;
    // vgatherdps takes i32 indices; fall back to the scalar copy when the
    // index range cannot be represented (or there is no full chunk).
    if chunks == 0 || stride == 0 || last > i32::MAX as usize || stride > i32::MAX as usize / 8 {
        for (j, o) in out.iter_mut().enumerate() {
            *o = src[offset + j * stride];
        }
        return;
    }
    let (o, s) = (offset as i32, stride as i32);
    let mut idx = _mm256_setr_epi32(
        o,
        o + s,
        o + 2 * s,
        o + 3 * s,
        o + 4 * s,
        o + 5 * s,
        o + 6 * s,
        o + 7 * s,
    );
    let step = _mm256_set1_epi32(8 * s);
    let op = out.as_mut_ptr();
    for i in 0..chunks {
        _mm256_storeu_ps(op.add(i * 8), _mm256_i32gather_ps(src.as_ptr(), idx, 4));
        idx = _mm256_add_epi32(idx, step);
    }
    for j in chunks * 8..n {
        out[j] = src[offset + j * stride];
    }
}
