//! Dense scaled-dot-product softmax attention — the O(N²·d) baseline the
//! native MiTA path is checked against and benchmarked over. Blocked over
//! query rows with one reusable score buffer from the [`Workspace`], so
//! steady-state calls are allocation-free; parallelism lives one level up
//! in the batched (example × head) executor of [`crate::kernels::api`].

use std::time::Instant;

use crate::kernels::linalg::{
    gather_head, matmul_nt, scatter_head, softmax_rows_scaled, weighted_row_sum,
};
use crate::kernels::profile::{self, Op};
use crate::kernels::workspace::Workspace;

/// Query rows per block; the score scratch is `min(QB, n) × n` floats.
const QB: usize = 32;

/// Single-head dense attention: `out = softmax(Q Kᵀ / √d) V` for row-major
/// `[n, d]` inputs, scratch from `ws`.
pub fn dense_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    ws: &mut Workspace,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d, "q must be [n, d]");
    assert_eq!(k.len(), n * d, "k must be [n, d]");
    assert_eq!(v.len(), n * d, "v must be [n, d]");
    assert_eq!(out.len(), n * d, "out must be [n, d]");
    if n == 0 || d == 0 {
        return;
    }
    let t_attend = Instant::now();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = ws.take_f32("dense.scores", QB.min(n) * n);
    for r0 in (0..n).step_by(QB) {
        let rows = QB.min(n - r0);
        let sblk = &mut s[..rows * n];
        matmul_nt(&q[r0 * d..(r0 + rows) * d], k, rows, n, d, sblk);
        // The 1/√d logit scale is folded into the softmax's exp pass —
        // one fewer full traversal of the score block per query block.
        softmax_rows_scaled(sblk, rows, n, scale);
        for (r, orow) in out[r0 * d..(r0 + rows) * d].chunks_exact_mut(d).enumerate() {
            weighted_row_sum(&sblk[r * n..(r + 1) * n], v, d, orow);
        }
    }
    ws.give_f32("dense.scores", s);
    profile::record_since(Op::DenseAttend, t_attend);
}

/// Multi-head dense attention over model-dim layout: `[n, dim]` inputs
/// where head `h` owns columns `[h·dh, (h+1)·dh)`, `dim = heads · dh`.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_mh(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    heads: usize,
    dim: usize,
    ws: &mut Workspace,
    out: &mut [f32],
) {
    assert!(heads >= 1 && dim % heads == 0, "dim {dim} must divide into {heads} heads");
    assert_eq!(out.len(), n * dim, "out must be [n, dim]");
    if n == 0 || dim == 0 {
        return;
    }
    let dh = dim / heads;
    let mut qh = ws.take_f32("mh.q", n * dh);
    let mut kh = ws.take_f32("mh.k", n * dh);
    let mut vh = ws.take_f32("mh.v", n * dh);
    let mut oh = ws.take_f32("mh.out", n * dh);
    for h in 0..heads {
        gather_head(q, n, dim, dh, h, &mut qh);
        gather_head(k, n, dim, dh, h, &mut kh);
        gather_head(v, n, dim, dh, h, &mut vh);
        dense_attention(&qh, &kh, &vh, n, dh, ws, &mut oh);
        scatter_head(&oh, n, dim, dh, h, out);
    }
    ws.give_f32("mh.q", qh);
    ws.give_f32("mh.k", kh);
    ws.give_f32("mh.v", vh);
    ws.give_f32("mh.out", oh);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    /// f64 reference for one query row.
    fn ref_row(qrow: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> Vec<f64> {
        let scale = 1.0 / (d as f64).sqrt();
        let logits: Vec<f64> = (0..n)
            .map(|j| {
                let mut acc = 0.0f64;
                for c in 0..d {
                    acc += qrow[c] as f64 * k[j * d + c] as f64;
                }
                acc * scale
            })
            .collect();
        let mx = logits.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let ps: Vec<f64> = logits.iter().map(|l| (l - mx).exp()).collect();
        let den: f64 = ps.iter().sum();
        let mut out = vec![0.0f64; d];
        for (j, p) in ps.iter().enumerate() {
            for c in 0..d {
                out[c] += p / den * v[j * d + c] as f64;
            }
        }
        out
    }

    #[test]
    fn matches_f64_reference() {
        let mut rng = Rng::new(3);
        let mut ws = Workspace::new();
        for (n, d) in [(1, 4), (7, 3), (65, 16), (128, 32)] {
            let q: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let k: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let v: Vec<f32> = (0..n * d).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let mut out = vec![0.0f32; n * d];
            dense_attention(&q, &k, &v, n, d, &mut ws, &mut out);
            for r in [0, n / 2, n - 1] {
                let want = ref_row(&q[r * d..(r + 1) * d], &k, &v, n, d);
                for c in 0..d {
                    let got = out[r * d + c] as f64;
                    assert!(
                        (got - want[c]).abs() < 1e-4,
                        "n={n} d={d} row {r} col {c}: {got} vs {}",
                        want[c]
                    );
                }
            }
        }
    }

    #[test]
    fn uniform_keys_average_values() {
        // Identical keys ⇒ uniform attention ⇒ output = mean of values.
        let (n, d) = (9, 5);
        let q: Vec<f32> = (0..n * d).map(|i| (i % 7) as f32).collect();
        let k = vec![1.0f32; n * d];
        let v: Vec<f32> = (0..n * d).map(|i| i as f32).collect();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * d];
        dense_attention(&q, &k, &v, n, d, &mut ws, &mut out);
        for c in 0..d {
            let mean: f32 = (0..n).map(|j| v[j * d + c]).sum::<f32>() / n as f32;
            assert!((out[c] - mean).abs() < 1e-3, "col {c}: {} vs {mean}", out[c]);
        }
    }

    #[test]
    fn multihead_equals_per_head_calls() {
        let mut rng = Rng::new(5);
        let (n, heads, dh) = (33, 4, 8);
        let dim = heads * dh;
        let q: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let k: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let v: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut ws = Workspace::new();
        let mut got = vec![0.0f32; n * dim];
        dense_attention_mh(&q, &k, &v, n, heads, dim, &mut ws, &mut got);

        let mut want = vec![0.0f32; n * dim];
        let mut qh = vec![0.0f32; n * dh];
        let mut kh = vec![0.0f32; n * dh];
        let mut vh = vec![0.0f32; n * dh];
        let mut oh = vec![0.0f32; n * dh];
        for h in 0..heads {
            gather_head(&q, n, dim, dh, h, &mut qh);
            gather_head(&k, n, dim, dh, h, &mut kh);
            gather_head(&v, n, dim, dh, h, &mut vh);
            dense_attention(&qh, &kh, &vh, n, dh, &mut ws, &mut oh);
            scatter_head(&oh, n, dim, dh, h, &mut want);
        }
        assert_eq!(got, want);
    }
}
