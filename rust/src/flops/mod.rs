//! Analytical FLOPs model — reproduces the FLOPs columns of Tabs. 2/3/4 and
//! the complexity claims of Sec. 3.2 (O(N(m+ks)) vs O(N²)).
//!
//! Convention: 1 multiply-accumulate = 2 FLOPs; softmax/norm/activation
//! costs are counted at 1 FLOP per element pass (they are negligible next
//! to the matmuls, but included for honesty at small N).

use crate::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};
use crate::model::ModelConfig;
use crate::runtime::ModelCfg;

/// FLOPs of one attention layer's token mixing for a single example,
/// excluding the qkv/proj projections (those are shared across variants).
pub fn attention_flops(cfg: &ModelCfg) -> f64 {
    let n = cfg.num_tokens() as f64;
    let d = (cfg.dim / cfg.heads) as f64;
    let h = cfg.heads as f64;
    let a = &cfg.attention;
    let m = a.m as f64;
    let k = a.k as f64;
    let s = a.s as f64;

    let per_head = match a.kind.as_str() {
        "standard" => {
            // QK^T + PV matmuls + softmax pass.
            2.0 * n * n * d + 2.0 * n * n * d + 3.0 * n * n
        }
        "linear" => {
            // K^T V (d x d fast weights) + Q (KV) + normalizer.
            2.0 * n * d * d + 2.0 * n * d * d + 2.0 * n * d
        }
        "agent" | "mita_compress" => {
            // A K^T + (softmax) A V  -> m-width summary; then Q A^T + PV.
            2.0 * m * n * d + 2.0 * m * n * d + 3.0 * m * n
                + 2.0 * n * m * d + 2.0 * n * m * d + 3.0 * n * m
        }
        "mita" | "mita_route" => {
            // Landmark scores K Q̃^T (shared by Eq. 7 + Eq. 8).
            let scores = 2.0 * n * m * d;
            // Landmark values V^T softmax(S) (shared expert) — only if
            // compression branch present.
            let shared = if a.kind == "mita" { 2.0 * n * m * d + 2.0 * n * m } else { 0.0 };
            // Routing logits Q Q̃^T.
            let routing = 2.0 * n * m * d;
            // Final attention over m + k*s pairs per query (routed-only
            // variant attends to k*s pairs).
            let attended = if a.kind == "mita" { m + k * s } else { k * s };
            let attn = 2.0 * n * attended * d * 2.0 + 3.0 * n * attended;
            // top-k selection ~ n log2(k) comparisons per expert column.
            let topk = m * n * (k.log2().max(1.0));
            scores + shared + routing + attn + topk
        }
        other => panic!("unknown attention kind {other:?}"),
    };
    per_head * h
}

/// FLOPs of one full forward pass for a single example.
pub fn model_flops(cfg: &ModelCfg) -> f64 {
    let n = cfg.num_tokens() as f64;
    let dim = cfg.dim as f64;
    let hidden = dim * cfg.mlp_ratio;
    let depth = cfg.depth as f64;

    // Embedding.
    let embed = if cfg.task == "lra" {
        n * dim // table lookup + pos add
    } else {
        let pdim = (cfg.patch * cfg.patch * cfg.channels) as f64;
        2.0 * n * pdim * dim
    };

    // Per block: qkv (3 d²), proj (d²), mlp (2 d·hidden), 2 layernorms,
    // + the attention mixing itself.
    let per_block = 2.0 * n * dim * (3.0 * dim)
        + 2.0 * n * dim * dim
        + 2.0 * n * dim * hidden * 2.0
        + 2.0 * 5.0 * n * dim
        + attention_flops(cfg);
    let head = 2.0 * dim * cfg.num_classes as f64 * if cfg.task == "seg_image" { n } else { 1.0 };

    embed + depth * per_block + head
}

/// Parameter count of the model (mirrors model.init_params).
pub fn param_count(cfg: &ModelCfg) -> usize {
    let dim = cfg.dim;
    let hidden = (dim as f64 * cfg.mlp_ratio) as usize;
    let n = cfg.num_tokens();
    let mut p = 0usize;
    // Blocks.
    let mut block = 0usize;
    block += 2 * dim; // ln1
    block += dim * 3 * dim + 3 * dim; // qkv
    block += dim * dim + dim; // proj
    block += 2 * dim; // ln2
    block += dim * hidden + hidden; // fc1
    block += hidden * dim + dim; // fc2
    if cfg.attention.landmark == "learned" {
        block += cfg.attention.m * dim;
    }
    if cfg.dwc {
        block += if cfg.task == "lra" { 3 * dim } else { 9 * dim };
    }
    if cfg.gate {
        block += dim * dim + dim;
    }
    p += cfg.depth * block;
    p += 2 * dim; // ln_f
    p += n * dim; // pos
    p += dim * cfg.num_classes + cfg.num_classes; // head
    if cfg.task == "lra" {
        p += cfg.vocab * dim;
    } else {
        p += cfg.patch * cfg.patch * cfg.channels * dim + dim;
    }
    p
}

// ---------------------------------------------------------------------------
// Native model subsystem (crate::model) accounting
// ---------------------------------------------------------------------------

/// FLOPs of one *native* attention op for a single example — the token
/// mixing the registry kernel actually executes, summed over heads and
/// excluding the qkv/proj projections (those are counted per block in
/// [`native_model_flops`]). `attn.mita` mirrors the kernel's stages:
/// landmark pooling, landmark scores, routing logits + top-k selection,
/// then per-query attention over the expert's k gathered KV pairs.
pub fn native_attention_flops(cfg: &ModelConfig, kernel: &str) -> f64 {
    let n = cfg.seq_len as f64;
    let d = cfg.head_dim() as f64;
    let h = cfg.heads as f64;
    let per_head = match kernel {
        OP_ATTN_DENSE => 2.0 * n * n * d + 2.0 * n * n * d + 3.0 * n * n,
        OP_ATTN_MITA => {
            let m = cfg.mita.m.clamp(1, cfg.seq_len) as f64;
            let k = cfg.mita.k.clamp(1, cfg.seq_len) as f64;
            let landmarks = n * d; // adaptive pooling over Q
            let scores = 2.0 * n * m * d; // K Q̃ᵀ
            let routing = 2.0 * n * m * d; // Q Q̃ᵀ + argmax
            let topk = m * n * k.log2().max(1.0); // top-k selection
            let attn = 2.0 * n * k * d * 2.0 + 3.0 * n * k; // per-query over k pairs
            landmarks + scores + routing + topk + attn
        }
        other => panic!("unknown native attention kernel {other:?}"),
    };
    per_head * h
}

/// FLOPs of one full native-model forward pass for a single example:
/// embedding + per-block (qkv, attention via the block's kernel, proj,
/// MLP, layernorms) + final LN, mean-pool, and classifier head. This is
/// the model-level complexity column of `BENCH_model_native.json`.
pub fn native_model_flops(cfg: &ModelConfig) -> f64 {
    let n = cfg.seq_len as f64;
    let dim = cfg.dim as f64;
    let hidden = cfg.mlp_hidden as f64;

    let embed = 2.0 * n * dim; // table lookup + positional add
    let mut total = embed;
    for kernel in &cfg.block_kernels {
        total += 2.0 * n * dim * (3.0 * dim) // qkv projections
            + 2.0 * n * dim * dim // output projection
            + 2.0 * n * dim * hidden * 2.0 // MLP fc1 + fc2
            + 2.0 * 5.0 * n * dim // two layernorms
            + native_attention_flops(cfg, kernel);
    }
    total + 5.0 * n * dim // final layernorm
        + n * dim // mean pool
        + 2.0 * dim * cfg.classes as f64 // head
}

/// Human-readable GFLOPs.
pub fn gflops(f: f64) -> String {
    if f >= 1e9 {
        format!("{:.2}G", f / 1e9)
    } else {
        format!("{:.1}M", f / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::AttentionCfg;

    fn cfg(kind: &str, n_side: usize, m: usize, k: usize) -> ModelCfg {
        ModelCfg {
            task: "cls_image".into(),
            depth: 3,
            dim: 64,
            heads: 4,
            mlp_ratio: 4.0,
            num_classes: 10,
            attention: AttentionCfg {
                kind: kind.into(),
                m,
                k,
                s: 1,
                landmark: "pool2d".into(),
                cap_factor: 2,
                use_pallas: false,
            },
            image_hw: (n_side * 4, n_side * 4),
            patch: 4,
            channels: 3,
            seq_len: 1024,
            vocab: 32,
            pool: "mean".into(),
            dwc: false,
            gate: false,
        }
    }

    #[test]
    fn standard_is_quadratic_mita_is_linear() {
        // Doubling the token count 4x (side 2x) should ~16x standard
        // attention flops but only ~4x MiTA's.
        let std_1 = attention_flops(&cfg("standard", 8, 16, 16));
        let std_2 = attention_flops(&cfg("standard", 16, 16, 16));
        let mita_1 = attention_flops(&cfg("mita", 8, 16, 16));
        let mita_2 = attention_flops(&cfg("mita", 16, 16, 16));
        let std_ratio = std_2 / std_1;
        let mita_ratio = mita_2 / mita_1;
        assert!(std_ratio > 14.0 && std_ratio < 18.0, "std ratio {std_ratio}");
        assert!(mita_ratio > 3.5 && mita_ratio < 4.5, "mita ratio {mita_ratio}");
    }

    #[test]
    fn mita_cheaper_than_standard_at_scale() {
        // At N=1024 with m=k=32, MiTA must be far cheaper.
        let c_std = cfg("standard", 32, 32, 32);
        let c_mita = cfg("mita", 32, 32, 32);
        assert!(attention_flops(&c_std) / attention_flops(&c_mita) > 4.0);
    }

    #[test]
    fn route_only_cheaper_than_full_mita() {
        let full = attention_flops(&cfg("mita", 16, 16, 16));
        let route = attention_flops(&cfg("mita_route", 16, 16, 16));
        assert!(route < full);
    }

    #[test]
    fn param_count_matches_known_model() {
        // Cross-checked against jax param tree of the quickstart config
        // (depth 2, dim 64, heads 4, 16x16 img, patch 4, 10 classes).
        let mut c = cfg("mita", 4, 4, 4);
        c.depth = 2;
        // blocks: 2*(128 + 12480 + 4160 + 128 + 16640 + 16448) = 99_968
        // ln_f 128, pos 16*64=1024, head 650, patch 48*64+64=3136
        assert_eq!(param_count(&c), 99_968 + 128 + 1024 + 650 + 3136);
    }

    #[test]
    fn model_flops_dominated_by_blocks() {
        let c = cfg("standard", 8, 16, 16);
        assert!(model_flops(&c) > attention_flops(&c) * c.depth as f64);
    }

    fn native_cfg(n: usize, kernel: &str) -> ModelConfig {
        let mut c = ModelConfig::new(32, n, 64, 4, 2, 128, 10, kernel);
        // Fix (m, k) across n so the scaling test isolates the N term.
        c.mita = crate::kernels::MitaKernelConfig { m: 16, k: 64, cap_factor: 2, block_q: 16 };
        c
    }

    #[test]
    fn native_dense_blocks_quadratic_mita_blocks_linear() {
        // 4x the tokens: ~16x dense-block attention, ~4x MiTA-block.
        let dense_r = native_attention_flops(&native_cfg(4096, OP_ATTN_DENSE), OP_ATTN_DENSE)
            / native_attention_flops(&native_cfg(1024, OP_ATTN_DENSE), OP_ATTN_DENSE);
        let mita_r = native_attention_flops(&native_cfg(4096, OP_ATTN_MITA), OP_ATTN_MITA)
            / native_attention_flops(&native_cfg(1024, OP_ATTN_MITA), OP_ATTN_MITA);
        assert!(dense_r > 14.0 && dense_r < 18.0, "dense ratio {dense_r}");
        assert!(mita_r > 3.5 && mita_r < 4.5, "mita ratio {mita_r}");
    }

    #[test]
    fn native_model_flops_sum_blocks_and_respect_kernels() {
        let mita = native_cfg(1024, OP_ATTN_MITA);
        let dense = mita.clone().with_kernel(OP_ATTN_DENSE);
        assert!(native_model_flops(&dense) > native_model_flops(&mita));
        // A mixed model sits strictly between the uniform ones.
        let mut mixed = mita.clone();
        mixed.block_kernels[0] = OP_ATTN_DENSE.to_string();
        let (lo, mid, hi) =
            (native_model_flops(&mita), native_model_flops(&mixed), native_model_flops(&dense));
        assert!(lo < mid && mid < hi, "{lo} < {mid} < {hi}");
        // Model total strictly exceeds its attention mixing alone.
        let attn_total = 2.0 * native_attention_flops(&mita, OP_ATTN_MITA);
        assert!(native_model_flops(&mita) > attn_total);
    }
}
