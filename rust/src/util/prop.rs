//! Seeded property-testing harness (std-only proptest substitute).
//!
//! `run_prop(cases, |g| { ... })` executes a closure over `cases` generated
//! inputs; on failure it retries with progressively simpler size hints to
//! report a smaller counterexample, then panics with the failing seed so
//! the case is reproducible.

use crate::data::rng::Rng;

/// Input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Soft size hint (shrinks on failure retries).
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.range_f32(lo, hi)).collect()
    }

    pub fn vec_usize_below(&mut self, len: usize, n: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.below(n)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }
}

/// Run `property` over `cases` seeded inputs. The property panics (assert!)
/// to signal failure.
pub fn run_prop<F: FnMut(&mut Gen)>(cases: usize, property: F) {
    run_prop_seeded(0xC0DE, cases, property)
}

pub fn run_prop_seeded<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut property: F) {
    for case in 0..cases {
        let mut g = Gen { rng: Rng::derive(seed, &[case as u64]), size: 16 + case % 48 };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            let size = 16 + case % 48;
            panic!("property failed on case {case} (seed {seed:#x}, size {size}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run_prop(50, |g| {
            let n = g.usize_in(1, 100);
            assert!(n >= 1 && n <= 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn failing_property_reports_case() {
        run_prop(50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 90, "found {n}");
        });
    }

    #[test]
    fn generators_deterministic_per_case() {
        let mut first = Vec::new();
        run_prop_seeded(7, 5, |g| first.push(g.usize_in(0, 1000)));
        let mut second = Vec::new();
        run_prop_seeded(7, 5, |g| second.push(g.usize_in(0, 1000)));
        assert_eq!(first, second);
    }
}
