//! Minimal recursive-descent JSON parser **and emitter** (std-only).
//!
//! The build environment is fully offline with no serde in the vendored
//! crate set, so the manifest contract (artifacts/manifest.json) and the
//! service wire protocol (docs/PROTOCOL.md) are handled with this module
//! instead. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP. [`Value::render`] emits compact JSON with object keys
//! sorted, so output is deterministic and diffable.

use std::collections::HashMap;
use std::fmt;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(HashMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------

    pub fn as_obj(&self) -> Result<&HashMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            other => bail!("expected object, got {other}"),
        }
    }

    pub fn as_arr(&self) -> Result<&Vec<Value>> {
        match self {
            Value::Arr(a) => Ok(a),
            other => bail!("expected array, got {other}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => bail!("expected number, got {other}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other}"),
        }
    }

    /// Member lookup with a helpful error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing key {key:?}"))
    }

    /// Optional member lookup (missing or null -> None).
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => match m.get(key) {
                Some(Value::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    // ---- builders ---------------------------------------------------------

    /// An object from `(key, value)` pairs.
    pub fn obj<K: Into<String>>(pairs: impl IntoIterator<Item = (K, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// A number value from anything convertible to f64. Integers up to
    /// 2^53 and every f32 round-trip exactly through [`Value::render`].
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    // ---- emit -------------------------------------------------------------

    /// Compact JSON text. Object keys are emitted sorted so the output is
    /// deterministic; non-finite numbers (not representable in JSON)
    /// render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                let mut keys: Vec<&String> = m.keys().collect();
                keys.sort_unstable();
                out.push('{');
                for (i, k) in keys.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    m[*k].write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    use std::fmt::Write as _;
    let negative_zero = n == 0.0 && n.is_sign_negative();
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 && !negative_zero {
        let _ = write!(out, "{}", n as i64);
    } else {
        // `{}` on f64 prints the shortest representation that round-trips
        // (negative zero takes this branch too — "-0" is valid JSON and
        // keeps the sign bit, which the i64 cast would drop).
        let _ = write!(out, "{n}");
    }
}

fn write_str(s: &str, out: &mut String) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => write!(f, "{n}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Arr(a) => write!(f, "array[{}]", a.len()),
            Value::Obj(m) => write!(f, "object[{}]", m.len()),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                other => bail!("expected ',' or '}}', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(arr));
                }
                other => bail!("expected ',' or ']', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().context("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).context("bad \\u code point")?);
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse(r#""hi\nthere""#).unwrap(), Value::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null, "e": false}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(v.opt("d").is_none());
        assert!(!v.get("e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse(r#"{"a" 1}"#).is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
    }

    #[test]
    fn usize_accessor_guards() {
        assert_eq!(Value::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Value::parse("-1").unwrap().as_usize().is_err());
        assert!(Value::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn render_roundtrips_and_sorts_keys() {
        let v = Value::obj([
            ("b", Value::num(2.0)),
            ("a", Value::Arr(vec![Value::num(1.5), Value::Bool(true), Value::Null])),
            ("s", Value::str("he said \"hi\"\n")),
        ]);
        let text = v.render();
        assert_eq!(text, r#"{"a":[1.5,true,null],"b":2,"s":"he said \"hi\"\n"}"#);
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn render_numbers_roundtrip() {
        for x in [0.0f64, -1.0, 42.0, 0.1, -3.5e2, 1.0e16, f32::MAX as f64, 1e-7] {
            let text = Value::Num(x).render();
            assert_eq!(Value::parse(&text).unwrap().as_f64().unwrap(), x, "{text}");
        }
        // f32 payloads survive the f64 wire format exactly — including
        // the sign bit of negative zero (bitwise comparison; -0.0 == 0.0
        // under float equality would mask losing it).
        for x in [0.1f32, f32::MIN_POSITIVE, 1.0 / 3.0, -2.718_281_7, -0.0] {
            let text = Value::Num(x as f64).render();
            let back = Value::parse(&text).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), x.to_bits(), "{text}");
        }
        assert_eq!(Value::Num(-0.0).render(), "-0");
        assert_eq!(Value::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
            "version": 2,
            "artifacts": {"q.init": {"file": "q.init.hlo.txt",
                "inputs": [{"shape": [], "dtype": "i32"}]}}
        }"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 2);
        let art = v.get("artifacts").unwrap().get("q.init").unwrap();
        assert_eq!(art.get("file").unwrap().as_str().unwrap(), "q.init.hlo.txt");
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0].get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 0);
    }
}
