//! Std-only utilities replacing unavailable third-party crates (the build
//! environment is offline; only the `xla` closure is vendored).
//!
//! - [`json`]: recursive-descent JSON parser (replaces serde_json).
//! - [`cli`]: tiny argv parser (replaces clap).
//! - [`prop`]: seeded property-testing harness (replaces proptest).
//! - [`bench`]: timing harness used by the `cargo bench` binaries
//!   (replaces criterion).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
