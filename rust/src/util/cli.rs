//! Tiny argv parser (std-only clap substitute).
//!
//! Grammar: `mita [--global-flag v] <subcommand> [positionals] [--flag v]
//! [--switch]`. Flags may appear anywhere after the subcommand; `--flag=v`
//! and `--flag v` are both accepted.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

/// Flag names that take a value (everything else with `--` is a switch).
pub fn parse(argv: &[String], valued: &[&str]) -> Result<Args> {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if valued.contains(&name) {
                i += 1;
                let v = argv.get(i).with_context(|| format!("--{name} needs a value"))?;
                out.flags.insert(name.to_string(), v.clone());
            } else {
                out.switches.push(name.to_string());
            }
        } else if out.subcommand.is_empty() {
            out.subcommand = a.clone();
        } else {
            out.positionals.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

impl Args {
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow::anyhow!("--{name}={s}: {e}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn positional(&self, i: usize, what: &str) -> Result<&str> {
        match self.positionals.get(i) {
            Some(s) => Ok(s.as_str()),
            None => bail!("missing required argument <{what}>"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_and_positionals() {
        let argv = v(&["train", "t2_std", "--steps", "100", "--verbose", "--lr=0.1"]);
        let a = parse(&argv, &["steps"]).unwrap();
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.positional(0, "bundle").unwrap(), "t2_std");
        assert_eq!(a.flag("steps"), Some("100"));
        assert_eq!(a.flag("lr"), Some("0.1"));
        assert!(a.has("verbose"));
        assert_eq!(a.flag_parse("steps", 0usize).unwrap(), 100);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&v(&["train", "--steps"]), &["steps"]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&v(&["info"]), &[]).unwrap();
        assert_eq!(a.flag_or("prefix", ""), "");
        assert_eq!(a.flag_parse("batches", 16usize).unwrap(), 16);
        assert!(a.positional(0, "x").is_err());
    }
}
