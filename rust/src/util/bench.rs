//! Timing harness for the `cargo bench` binaries (criterion substitute).
//!
//! Measures wall-clock over warmup + timed iterations and reports
//! mean/std/min plus derived throughput. Single-core deterministic
//! environment ⇒ simple statistics suffice.

use std::time::Instant;

use crate::coordinator::metrics::Streaming;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:40} iters={:4} mean={:10.3}ms std={:8.3}ms min={:10.3}ms",
            self.name,
            self.iters,
            self.mean_secs * 1e3,
            self.std_secs * 1e3,
            self.min_secs * 1e3
        )
    }

    /// Items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean_secs <= 0.0 {
            0.0
        } else {
            items_per_iter / self.mean_secs
        }
    }
}

/// Run `f` for `warmup` + `iters` iterations and collect timing stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Streaming::default();
    let mut min = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        s.push(dt);
        min = min.min(dt);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_secs: s.mean(),
        std_secs: s.std(),
        min_secs: min,
    }
}

/// Time-budgeted variant: run until `budget_secs` elapses (at least once).
pub fn bench_for<F: FnMut()>(name: &str, warmup: usize, budget_secs: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Streaming::default();
    let mut min = f64::INFINITY;
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        s.push(dt);
        min = min.min(dt);
        if start.elapsed().as_secs_f64() >= budget_secs {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: s.count() as usize,
        mean_secs: s.mean(),
        std_secs: s.std(),
        min_secs: min,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_secs >= 0.0);
        assert!(r.min_secs <= r.mean_secs + 1e-9);
    }

    #[test]
    fn bench_for_runs_at_least_once() {
        let mut n = 0;
        let r = bench_for("noop", 0, 0.0, || n += 1);
        assert!(n >= 1);
        assert!(r.iters >= 1);
    }

    #[test]
    fn throughput_derivation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_secs: 0.5,
            std_secs: 0.0,
            min_secs: 0.5,
        };
        assert!((r.throughput(10.0) - 20.0).abs() < 1e-12);
    }
}
