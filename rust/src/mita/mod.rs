//! Pure-Rust mirror of the MiTA routing math (kernels/ref.py) plus the
//! analysis metrics behind Figs. 3/4/8.
//!
//! The Rust side never recomputes attention itself on the request path —
//! that is the AOT artifacts' job — but the coordinator needs the routing
//! semantics for (a) analysis of trained models (overlap mIoU, token
//! pruning), and (b) property tests of the invariants the Pallas kernel's
//! host packing relies on.

pub mod analysis;
pub mod routing;

pub use analysis::{expert_query_overlap, selected_token_fraction};
pub use routing::{
    adaptive_pool_matrix, capacity, landmarks_pool1d, pack_by_expert, route_argmax, scores,
    topk_indices, PackResult,
};
