//! Analysis metrics over trained-model routing internals (the outputs of
//! the `analysis` artifact: per-layer top-k indices and query→expert
//! assignments).
//!
//! - [`expert_query_overlap`]: Fig. 8 — positional IoU between the key-value
//!   pairs an expert gathers and the queries routed to it. Low overlap means
//!   MiTA routes (information flows across regions) rather than clusters.
//! - [`selected_token_fraction`]: Fig. 4 — fraction of tokens selected by at
//!   least one expert; its decay over depth is the emergent token-pruning
//!   effect.

use std::collections::HashSet;

/// Mean IoU between expert key-value positions and routed-query positions.
///
/// `topk`: `[m * kk]` token indices gathered per expert (expert-major).
/// `assign`: `[n]` expert id per query. Experts with no routed queries are
/// skipped (IoU undefined), matching the paper's per-expert average.
pub fn expert_query_overlap(topk: &[usize], assign: &[usize], m: usize, kk: usize) -> f64 {
    assert_eq!(topk.len(), m * kk);
    let mut ious = Vec::with_capacity(m);
    for e in 0..m {
        let kv: HashSet<usize> = topk[e * kk..(e + 1) * kk].iter().copied().collect();
        let queries: HashSet<usize> =
            assign.iter().enumerate().filter(|&(_, &a)| a == e).map(|(i, _)| i).collect();
        if queries.is_empty() {
            continue;
        }
        let inter = kv.intersection(&queries).count();
        let union = kv.union(&queries).count();
        if union > 0 {
            ious.push(inter as f64 / union as f64);
        }
    }
    if ious.is_empty() {
        0.0
    } else {
        ious.iter().sum::<f64>() / ious.len() as f64
    }
}

/// Fraction of the n tokens selected by at least one expert's top-k set.
pub fn selected_token_fraction(topk: &[usize], n: usize) -> f64 {
    let distinct: HashSet<usize> = topk.iter().copied().collect();
    distinct.len() as f64 / n as f64
}

/// Per-token selection counts (how many experts picked each token) — used
/// to render the Fig. 4 heatmaps as ASCII/PGM.
pub fn selection_counts(topk: &[usize], n: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n];
    for &t in topk {
        assert!(t < n, "token index {t} out of range {n}");
        counts[t] += 1;
    }
    counts
}

/// Render a token-grid heatmap as ASCII art (row-major `gh x gw` grid).
pub fn ascii_heatmap(counts: &[usize], gh: usize, gw: usize) -> String {
    assert_eq!(counts.len(), gh * gw);
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    let ramp = [' ', '.', ':', '+', '*', '#', '@'];
    let mut out = String::with_capacity(gh * (gw + 1));
    for y in 0..gh {
        for x in 0..gw {
            let v = counts[y * gw + x];
            let idx = (v * (ramp.len() - 1) + max / 2) / max;
            out.push(ramp[idx.min(ramp.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_identical_sets_is_one() {
        // m=1 expert picks tokens {0,1}; queries 0 and 1 route to it; queries
        // beyond n=2 don't exist.
        let topk = vec![0, 1];
        let assign = vec![0, 0];
        assert!((expert_query_overlap(&topk, &assign, 1, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_disjoint_sets_is_zero() {
        let topk = vec![2, 3];
        let assign = vec![0, 0, 1, 1]; // queries 0,1 -> expert0; 2,3 -> expert1
        let topk2 = vec![2, 3, 0, 1]; // e0 gathers {2,3}, e1 gathers {0,1}
        assert_eq!(expert_query_overlap(&topk2, &assign, 2, 2), 0.0);
        let _ = topk;
    }

    #[test]
    fn empty_experts_skipped() {
        let topk = vec![0, 1, 2, 3];
        let assign = vec![0, 0]; // expert 1 gets no queries
        let v = expert_query_overlap(&topk, &assign, 2, 2);
        assert!((v - 1.0).abs() < 1e-12); // only expert 0 counted: {0,1} vs {0,1}
    }

    #[test]
    fn selected_fraction_counts_distinct() {
        let topk = vec![0, 0, 1, 1]; // experts overlap on tokens 0/1
        assert!((selected_token_fraction(&topk, 4) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn selection_counts_and_heatmap() {
        let topk = vec![0, 1, 1, 3];
        let counts = selection_counts(&topk, 4);
        assert_eq!(counts, vec![1, 2, 0, 1]);
        let art = ascii_heatmap(&counts, 2, 2);
        assert_eq!(art.lines().count(), 2);
        // Max-count cell uses the densest glyph.
        assert!(art.contains('@'));
    }
}
