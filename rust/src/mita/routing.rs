//! MiTA routing primitives: landmark pooling, landmark scores, top-k expert
//! construction, argmax routing, and the capacity packing used by the
//! Pallas kernel's host wrapper (mita.py) — re-implemented in Rust so the
//! invariants can be property-tested without a Python runtime.
//!
//! Matrix convention: row-major `[n, d]` slices, matching kernels/ref.py.
//!
//! All dense inner loops (landmark pooling, landmark scores, routing
//! logits, the top-k column gather) run through the dispatched SIMD ops
//! of [`crate::kernels::simd`] — the same canonical reduction order the
//! blocked kernels use, so the scalar definitions here and the blocked
//! implementations in [`crate::kernels::mita`] stay bit-identical.

use crate::kernels::linalg::{axpy, dot};

/// `[m, n]` adaptive average-pooling matrix (PyTorch AdaptiveAvgPool1d
/// windows): element r belongs to window i iff
/// `floor(i*n/m) <= r < floor((i+1)*n/m)`.
pub fn adaptive_pool_matrix(n: usize, m: usize) -> Vec<f32> {
    assert!(m >= 1 && m <= n);
    let mut mat = vec![0.0f32; m * n];
    for i in 0..m {
        let lo = i * n / m;
        let hi = (i + 1) * n / m;
        let w = 1.0 / (hi - lo) as f32;
        for r in lo..hi {
            mat[i * n + r] = w;
        }
    }
    mat
}

/// 1-D adaptive-average-pooled landmarks: q `[n, d]` -> `[m, d]`.
pub fn landmarks_pool1d(q: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * d];
    landmarks_pool1d_into(q, n, d, m, &mut out);
    out
}

/// Allocation-free core of [`landmarks_pool1d`]: same windows, same
/// accumulation order (so results are bit-identical), output into a
/// caller-owned `[m, d]` buffer.
pub fn landmarks_pool1d_into(q: &[f32], n: usize, d: usize, m: usize, out: &mut [f32]) {
    assert_eq!(q.len(), n * d);
    assert_eq!(out.len(), m * d);
    assert!(m >= 1 && m <= n);
    out.fill(0.0);
    for i in 0..m {
        let lo = i * n / m;
        let hi = (i + 1) * n / m;
        let w = 1.0 / (hi - lo) as f32;
        let orow = &mut out[i * d..(i + 1) * d];
        for r in lo..hi {
            axpy(w, &q[r * d..(r + 1) * d], orow);
        }
    }
}

/// Landmark scores S = K Q̃ᵀ / sqrt(d): `[n, m]` (Alg. 1 line 4).
pub fn scores(k: &[f32], q_land: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
    assert_eq!(k.len(), n * d);
    assert_eq!(q_land.len(), m * d);
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = vec![0.0f32; n * m];
    for r in 0..n {
        let krow = &k[r * d..(r + 1) * d];
        for i in 0..m {
            // Same dispatched dot (and therefore the same bits) as the
            // blocked matmul_nt path in kernels/mita's select_experts.
            s[r * m + i] = dot(krow, &q_land[i * d..(i + 1) * d]) * scale;
        }
    }
    s
}

/// Top-k row indices per expert column (Eq. 7): returns `[m, kk]` indices,
/// each column's picks sorted by descending score (ties: lower index first).
pub fn topk_indices(s: &[f32], n: usize, m: usize, kk: usize) -> Vec<usize> {
    let mut col = vec![0.0f32; n];
    let mut order = vec![0usize; n];
    let mut out = vec![0usize; m * kk];
    topk_indices_into(s, n, m, kk, &mut col, &mut order, &mut out);
    out
}

/// Allocation-free core of [`topk_indices`]: `col` is an `[n]` f32
/// scratch, `order` an `[n]` index scratch, `out` receives the `[m, kk]`
/// picks. Each expert's score column is first gathered contiguous (the
/// dispatched strided gather — AVX2 uses `vgatherdps`), so the selection
/// comparator reads a dense cache-line-friendly buffer instead of
/// striding through `[n, m]`. Selection uses an unstable partition +
/// prefix sort — identical results to a full stable sort because the
/// index tiebreak makes the comparator a total order, but O(n + k·log k)
/// per expert instead of O(n·log n).
pub fn topk_indices_into(
    s: &[f32],
    n: usize,
    m: usize,
    kk: usize,
    col: &mut [f32],
    order: &mut [usize],
    out: &mut [usize],
) {
    assert!(kk <= n);
    assert_eq!(col.len(), n);
    assert_eq!(order.len(), n);
    assert_eq!(out.len(), m * kk);
    if kk == 0 {
        return;
    }
    let gather = crate::kernels::simd::ops().gather_stride;
    for i in 0..m {
        gather(s, i, m, col);
        for (j, o) in order.iter_mut().enumerate() {
            *o = j;
        }
        let cmp = |a: &usize, b: &usize| {
            col[*b]
                .partial_cmp(&col[*a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        if kk < n {
            order.select_nth_unstable_by(kk - 1, cmp);
        }
        order[..kk].sort_unstable_by(cmp);
        out[i * kk..(i + 1) * kk].copy_from_slice(&order[..kk]);
    }
}

/// Argmax routing e(q) over logits Q Q̃ᵀ (s = 1): `[n]` expert ids.
pub fn route_argmax(q: &[f32], q_land: &[f32], n: usize, d: usize, m: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(n);
    for r in 0..n {
        let qrow = &q[r * d..(r + 1) * d];
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for i in 0..m {
            // Dispatched dot ⇒ bit-identical logits to the blocked
            // route_logits matmul in select_experts, so ties break the
            // same way (lower expert id) on both paths.
            let acc = dot(qrow, &q_land[i * d..(i + 1) * d]);
            if acc > best_v {
                best_v = acc;
                best = i;
            }
        }
        out.push(best);
    }
    out
}

/// Per-expert query capacity as used by the kernel host wrapper:
/// `ceil(ceil(n/m) * cap_factor / block_q) * block_q`.
pub fn capacity(n: usize, m: usize, cap_factor: usize, block_q: usize) -> usize {
    let base = n.div_ceil(m) * cap_factor;
    base.div_ceil(block_q) * block_q
}

/// Result of packing queries into per-expert slots (mirrors mita.py).
#[derive(Debug, Clone)]
pub struct PackResult {
    /// slot[q] = expert * cap + rank, or None if the query overflowed.
    pub slot: Vec<Option<usize>>,
    pub cap: usize,
    pub overflow: usize,
    /// queries per expert (before capacity truncation).
    pub counts: Vec<usize>,
}

/// Pack queries by expert assignment with bounded capacity — the static-
/// shape substitute for varlen batching (DESIGN.md §6).
pub fn pack_by_expert(assign: &[usize], m: usize, cap: usize) -> PackResult {
    let mut counts = vec![0usize; m];
    let mut raw = vec![0usize; assign.len()];
    let overflow = pack_into(assign, m, cap, &mut counts, &mut raw);
    let slot = raw.iter().map(|&s| if s == OVERFLOW { None } else { Some(s) }).collect();
    PackResult { slot, cap, overflow, counts }
}

/// Sentinel slot value marking a capacity-overflowed query in
/// [`pack_into`]'s output.
pub const OVERFLOW: usize = usize::MAX;

/// Allocation-free core of [`pack_by_expert`]: fills `counts` (`[m]`,
/// queries per expert before truncation) and `slot` (`[n]`, `expert · cap
/// + rank` or [`OVERFLOW`]) and returns the overflow count. Queries keep
/// arrival order within their expert (mirrors jnp.argsort(e, stable) +
/// rank-within-expert).
pub fn pack_into(
    assign: &[usize],
    m: usize,
    cap: usize,
    counts: &mut [usize],
    slot: &mut [usize],
) -> usize {
    assert_eq!(counts.len(), m, "counts must be [m]");
    assert_eq!(slot.len(), assign.len(), "slot must be [n]");
    counts.fill(0);
    let mut overflow = 0usize;
    for (&e, sl) in assign.iter().zip(slot.iter_mut()) {
        assert!(e < m, "expert id {e} out of range {m}");
        let r = counts[e];
        counts[e] += 1;
        *sl = if r < cap {
            e * cap + r
        } else {
            overflow += 1;
            OVERFLOW
        };
    }
    overflow
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_matrix_rows_sum_to_one() {
        for (n, m) in [(10, 3), (196, 25), (7, 7), (64, 16)] {
            let p = adaptive_pool_matrix(n, m);
            for i in 0..m {
                let s: f32 = (0..n).map(|r| p[i * n + r]).sum();
                assert!((s - 1.0).abs() < 1e-5, "n={n} m={m} row {i} sums to {s}");
            }
        }
    }

    #[test]
    fn pool_windows_partition() {
        let (n, m) = (14, 5); // the paper's non-divisible case
        let p = adaptive_pool_matrix(n, m);
        // Every column has exactly one nonzero entry.
        for r in 0..n {
            let nz = (0..m).filter(|&i| p[i * n + r] != 0.0).count();
            assert_eq!(nz, 1, "element {r} in {nz} windows");
        }
    }

    #[test]
    fn landmarks_of_constant_input() {
        let (n, d, m) = (12, 3, 4);
        let q = vec![2.5f32; n * d];
        let l = landmarks_pool1d(&q, n, d, m);
        assert!(l.iter().all(|&x| (x - 2.5).abs() < 1e-6));
    }

    #[test]
    fn topk_picks_highest() {
        // n=4 keys, m=1 expert, scores 0.1, 0.9, 0.5, 0.7 -> top2 = [1, 3]
        let s = vec![0.1f32, 0.9, 0.5, 0.7];
        let idx = topk_indices(&s, 4, 1, 2);
        assert_eq!(idx, vec![1, 3]);
    }

    #[test]
    fn route_argmax_basic() {
        // d=1: q = [1, -1], landmarks = [1, -1] -> q0 -> e0, q1 -> e1.
        let q = vec![1.0f32, -1.0];
        let l = vec![1.0f32, -1.0];
        assert_eq!(route_argmax(&q, &l, 2, 1, 2), vec![0, 1]);
    }

    #[test]
    fn capacity_rounding() {
        assert_eq!(capacity(196, 25, 2, 64), 64); // ceil(8*2/64)*64
        assert_eq!(capacity(1024, 16, 2, 64), 128);
        assert_eq!(capacity(64, 16, 1, 8), 8);
    }

    #[test]
    fn pack_no_overflow_when_cap_large() {
        let assign = vec![0, 1, 0, 2, 1, 0];
        let r = pack_by_expert(&assign, 3, 4);
        assert_eq!(r.overflow, 0);
        assert_eq!(r.counts, vec![3, 2, 1]);
        // Slots within an expert are consecutive ranks in arrival order.
        assert_eq!(r.slot[0], Some(0)); // e0 rank0
        assert_eq!(r.slot[2], Some(1)); // e0 rank1
        assert_eq!(r.slot[5], Some(2)); // e0 rank2
        assert_eq!(r.slot[1], Some(4)); // e1 rank0 (1*4+0)
    }

    #[test]
    fn pack_overflow_counted() {
        let assign = vec![0; 10];
        let r = pack_by_expert(&assign, 2, 4);
        assert_eq!(r.overflow, 6);
        assert_eq!(r.slot.iter().filter(|s| s.is_some()).count(), 4);
    }

    #[test]
    fn into_variants_match_allocating_apis() {
        // The `_into` cores used by the zero-alloc kernel must agree with
        // the original allocating functions on identical inputs.
        let (n, d, m, kk) = (23, 5, 4, 7);
        let q: Vec<f32> = (0..n * d).map(|i| ((i * 37 % 19) as f32) - 9.0).collect();
        let s: Vec<f32> = (0..n * m).map(|i| ((i * 53 % 29) as f32) * 0.25 - 3.0).collect();

        let mut lands = vec![1.0f32; m * d];
        landmarks_pool1d_into(&q, n, d, m, &mut lands);
        assert_eq!(lands, landmarks_pool1d(&q, n, d, m));

        let mut col = vec![0.0f32; n];
        let mut order = vec![0usize; n];
        let mut topk = vec![0usize; m * kk];
        topk_indices_into(&s, n, m, kk, &mut col, &mut order, &mut topk);
        assert_eq!(topk, topk_indices(&s, n, m, kk));

        let assign: Vec<usize> = (0..n).map(|i| i * 3 % m).collect();
        let cap = 4;
        let mut counts = vec![9usize; m];
        let mut slot = vec![0usize; n];
        let overflow = pack_into(&assign, m, cap, &mut counts, &mut slot);
        let want = pack_by_expert(&assign, m, cap);
        assert_eq!(overflow, want.overflow);
        assert_eq!(counts, want.counts);
        for (got, want) in slot.iter().zip(&want.slot) {
            match want {
                Some(s) => assert_eq!(got, s),
                None => assert_eq!(*got, OVERFLOW),
            }
        }
    }
}
