//! Markdown table/figure rendering for the experiment binaries — every
//! table binary prints rows in the paper's format plus a `paper:` column
//! annotation so EXPERIMENTS.md diffs are mechanical.

use std::fmt::Write as _;

/// Simple aligned markdown table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            out.push('|');
            for i in 0..ncols {
                let _ = write!(out, " {:width$} |", cells[i], width = widths[i]);
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{:-<width$}|", "", width = w + 2);
        }
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }
}

/// Format a ratio as the paper's "×N.N" speedup notation.
pub fn speedup(x: f64) -> String {
    format!("×{x:.1}")
}

/// Format an accuracy fraction as percent.
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

/// An ASCII line chart (for loss curves / Fig. 5 series in the terminal).
pub fn ascii_chart(series: &[(&str, Vec<(f64, f64)>)], width: usize, height: usize) -> String {
    if series.is_empty() || series.iter().all(|(_, pts)| pts.is_empty()) {
        return String::from("(no data)\n");
    }
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '#', '%'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts {
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "y: [{ymin:.3}, {ymax:.3}]  x: [{xmin:.1}, {xmax:.1}]");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "  {} = {}", marks[si % marks.len()], name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "Acc"]);
        t.row_strs(&["standard", "58.2"]);
        t.row_strs(&["mita", "58.9"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Method"));
        assert!(lines[2].contains("standard"));
        // All lines equal width.
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only one"]);
    }

    #[test]
    fn chart_handles_degenerate_input() {
        assert!(ascii_chart(&[], 10, 5).contains("no data"));
        let s = ascii_chart(&[("flat", vec![(0.0, 1.0), (1.0, 1.0)])], 20, 5);
        assert!(s.contains('*'));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(speedup(4.06), "×4.1");
        assert_eq!(pct(0.589), "58.9");
    }
}
