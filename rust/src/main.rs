//! `mita` — the L3 coordinator CLI.
//!
//! ```text
//! mita [--artifacts DIR] <command> [args]
//!
//! commands:
//!   info [--prefix P]                 list bundles from the manifest
//!   flops [--prefix P]                analytical FLOPs/params per bundle
//!   train <bundle> [--steps N] [--seed S] [--checkpoint F] [--warm-start F]
//!   eval <bundle> <checkpoint> [--batches N]
//!   serve <bundle> [--requests N] [--rate R] [--max-wait-ms W]
//!   table2|table3|table4|table5|table6|table7 [--steps N] [--seed S]
//!   figure5 [--requests N] | figure9 | figure10 | figures (3/4/8)
//!   complexity                        FLOPs-vs-N scaling table
//!   all [--steps N]                   every table + figure in sequence
//! ```

use std::path::PathBuf;

use anyhow::{bail, Result};

use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::{serve, Engine, ServeConfig, Trainer};
use mita::data::BatchSource;
use mita::flops;
use mita::harness::tables::{self, Opts};
use mita::harness::{figures, train_bundle};
use mita::report::Table;
use mita::runtime::Runtime;
use mita::util::cli;

const VALUED_FLAGS: &[&str] = &[
    "artifacts",
    "prefix",
    "steps",
    "seed",
    "checkpoint",
    "warm-start",
    "batches",
    "requests",
    "rate",
    "max-wait-ms",
    "queue-cap",
    "eval-batches",
    "log-every",
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, VALUED_FLAGS)?;
    let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
    let opts = Opts {
        steps: args.flag("steps").map(|s| s.parse()).transpose()?,
        seed: args.flag_parse("seed", 0i32)?,
    };

    match args.subcommand.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
        }
        "info" => {
            let rt = Runtime::load(&artifacts)?;
            let mut t =
                Table::new(&["bundle", "task", "attention", "N", "m", "k", "params", "artifacts"]);
            for name in rt.manifest().bundles_with_prefix(&args.flag_or("prefix", "")) {
                let b = rt.manifest().bundle(name)?;
                let mut arts: Vec<&str> = b.artifacts.keys().map(|s| s.as_str()).collect();
                arts.sort();
                t.row(&[
                    name.to_string(),
                    b.model.task.clone(),
                    b.model.attention.kind.clone(),
                    b.model.num_tokens().to_string(),
                    b.model.attention.m.to_string(),
                    b.model.attention.k.to_string(),
                    flops::param_count(&b.model).to_string(),
                    arts.join(","),
                ]);
            }
            print!("{}", t.render());
        }
        "flops" => {
            let rt = Runtime::load(&artifacts)?;
            let mut t = Table::new(&["bundle", "kind", "N", "attn FLOPs", "model FLOPs", "params"]);
            for name in rt.manifest().bundles_with_prefix(&args.flag_or("prefix", "")) {
                let b = rt.manifest().bundle(name)?;
                t.row(&[
                    name.to_string(),
                    b.model.attention.kind.clone(),
                    b.model.num_tokens().to_string(),
                    flops::gflops(flops::attention_flops(&b.model)),
                    flops::gflops(flops::model_flops(&b.model)),
                    flops::param_count(&b.model).to_string(),
                ]);
            }
            print!("{}", t.render());
        }
        "train" => {
            let bundle = args.positional(0, "bundle")?.to_string();
            let rt = Runtime::load(&artifacts)?;
            let warm = match args.flag("warm-start") {
                Some(p) => Some(mita::coordinator::checkpoint::load(std::path::Path::new(p))?),
                None => None,
            };
            let (trainer, oc) =
                train_bundle(&rt, &bundle, opts.seed, opts.steps, warm.as_deref())?;
            println!(
                "bundle={bundle} steps={} tail_loss={:.4} eval_loss={:.4} eval_acc={:.4}{} step_time={:.1}ms",
                oc.steps,
                oc.tail_loss,
                oc.eval.loss,
                oc.eval.accuracy,
                oc.eval.miou.map(|m| format!(" miou={m:.4}")).unwrap_or_default(),
                oc.mean_step_secs * 1e3,
            );
            println!("{}", figures::loss_curve_chart(&oc.loss_curve, &bundle));
            if let Some(path) = args.flag("checkpoint") {
                trainer.save_checkpoint(std::path::Path::new(path))?;
                println!("checkpoint saved to {path}");
            }
        }
        "eval" => {
            let bundle = args.positional(0, "bundle")?.to_string();
            let ckpt = PathBuf::from(args.positional(1, "checkpoint")?);
            let rt = Runtime::load(&artifacts)?;
            let ev = mita::coordinator::eval_checkpoint(
                &rt,
                &ckpt,
                &bundle,
                args.flag_parse("batches", 16usize)?,
            )?;
            println!(
                "bundle={bundle} eval_loss={:.4} eval_acc={:.4}{} ({} examples)",
                ev.loss,
                ev.accuracy,
                ev.miou.map(|m| format!(" miou={m:.4}")).unwrap_or_default(),
                ev.examples
            );
        }
        "serve" => {
            let bundle = args.positional(0, "bundle")?.to_string();
            let rt = Runtime::load(&artifacts)?;
            let spec = rt.manifest().bundle(&bundle)?.clone();
            let predict = rt.manifest().bundle_artifact(&bundle, "predict")?.to_string();
            let init = rt.manifest().bundle_artifact(&bundle, "init").map(str::to_string);
            drop(rt); // the engine thread owns its own runtime
            let engine = Engine::spawn(artifacts.clone(), vec![predict])?;
            // Bind weights: --checkpoint if given, else the init artifact.
            match args.flag("checkpoint") {
                Some(path) => {
                    let params =
                        mita::coordinator::checkpoint::load(std::path::Path::new(path))?;
                    engine.handle().bind_tensors(&bundle, params)?;
                }
                None => {
                    engine.handle().bind_init(&bundle, &init?, 0, spec.param_count())?;
                }
            }
            let cfg = ServeConfig {
                bundle: bundle.clone(),
                binding: bundle.clone(),
                requests: args.flag_parse("requests", 256usize)?,
                rate: args.flag_parse("rate", 0.0f64)?,
                queue_cap: args.flag_parse("queue-cap", 128usize)?,
                policy: BatchPolicy {
                    max_batch: spec.train.batch_size,
                    max_wait: std::time::Duration::from_millis(
                        args.flag_parse("max-wait-ms", 5u64)?,
                    ),
                },
            };
            let report = serve(&engine.handle(), &spec, &bundle, &cfg)?;
            println!("{}", report.row());
            engine.shutdown();
        }
        "table2" => {
            tables::table2(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table3" => {
            tables::table3(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table4" => {
            tables::table4(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table5" => {
            tables::table5(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table6" => {
            tables::table6(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table7" => {
            tables::table7(&Runtime::load(&artifacts)?, &opts)?;
        }
        "complexity" => {
            tables::complexity_table(&Runtime::load(&artifacts)?)?;
        }
        "figure5" => {
            let rt = Runtime::load(&artifacts)?;
            figures::figure5(&artifacts, &rt, args.flag_parse("requests", 64usize)?)?;
        }
        "figure9" => {
            figures::figure9(&Runtime::load(&artifacts)?, opts.seed)?;
        }
        "figure10" => {
            figures::figure10(&Runtime::load(&artifacts)?, opts.seed)?;
        }
        "figures" => {
            let rt = Runtime::load(&artifacts)?;
            figures::figures34(&rt, opts.seed)?;
            figures::figure8(&rt, opts.seed)?;
        }
        "all" => {
            let rt = Runtime::load(&artifacts)?;
            tables::table2(&rt, &opts)?;
            tables::table3(&rt, &opts)?;
            tables::table4(&rt, &opts)?;
            tables::table5(&rt, &opts)?;
            tables::table6(&rt, &opts)?;
            tables::table7(&rt, &opts)?;
            tables::complexity_table(&rt)?;
            figures::figures34(&rt, opts.seed)?;
            figures::figure8(&rt, opts.seed)?;
            figures::figure9(&rt, opts.seed)?;
            figures::figure10(&rt, opts.seed)?;
            figures::figure5(&artifacts, &rt, args.flag_parse("requests", 64usize)?)?;
        }
        // Utility used by examples/tests to sanity-check one bundle quickly.
        "quickcheck" => {
            let rt = Runtime::load(&artifacts)?;
            let bundle = args.flag_or("prefix", "quickstart");
            let spec = rt.manifest().bundle(&bundle)?.clone();
            let source = BatchSource::for_bundle(&spec)?;
            let mut trainer = Trainer::new(&rt, &bundle, 0)?;
            trainer.train(&source, 5, 1)?;
            let ev = trainer.eval(&source, 2)?;
            println!("quickcheck {bundle}: loss={:.3} acc={:.3}", ev.loss, ev.accuracy);
        }
        other => bail!("unknown command {other:?} (try `mita help`)"),
    }
    Ok(())
}

const HELP: &str = r#"mita — MiTA attention coordinator (rust + JAX/Pallas AOT)

usage: mita [--artifacts DIR] <command> [args]

inspection:
  info [--prefix P]        list bundles from the manifest
  flops [--prefix P]       analytical FLOPs/params per bundle
  complexity               attention FLOPs scaling vs N

single runs:
  train <bundle> [--steps N] [--seed S] [--checkpoint F] [--warm-start F]
  eval <bundle> <checkpoint> [--batches N]
  serve <bundle> [--requests N] [--rate R] [--max-wait-ms W] [--queue-cap C]

paper reproduction (see DESIGN.md experiment index):
  table2   from-scratch image classification (attention varied only)
  table3   model-level comparison
  table4   dense prediction (mIoU + FLOPs reduction)
  table5   synthetic LRA benchmark (acc + train throughput)
  table6   ablations (landmarks, m x k, compress/route)
  table7   finetuning pretrained standard-attn params
  figure5  inference throughput vs N (serving benchmark)
  figure9  train-with-X / infer-with-Y generalization matrix
  figure10 (m, k) generalization grid
  figures  figures 3/4 (expert heatmaps) + 8 (overlap)
  all      everything above in sequence
"#;
