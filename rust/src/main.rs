//! `mita` — the L3 coordinator CLI.
//!
//! ```text
//! mita [--artifacts DIR] <command> [args]
//!
//! commands:
//!   info [--prefix P]                 list bundles from the manifest
//!   flops [--prefix P]                analytical FLOPs/params per bundle
//!   train <bundle> [--steps N] [--seed S] [--checkpoint F] [--warm-start F]
//!   eval <bundle> <checkpoint> [--batches N]
//!   serve [<bundle>] [--workload bundle|attn|model] [--listen ADDR] [--replicas N] ...
//!   client --addr ADDR <health|attention|model-forward|stats|metrics|trace
//!          |check-prometheus|shutdown> [--retries N] ...
//!   native-check [--n N] [--dim D] [--heads H] [--m M] [--k K]
//!   model-check [--seq-len N] [--dim D] [--heads H] [--depth L]
//!   train-native [--task T] [--steps N] [--lr X] [--batch B] [--kernel mita|dense]
//!                [--checkpoint-out F] [--curve-out F]
//!   table2|table3|table4|table5|table6|table7 [--steps N] [--seed S]
//!   figure5 [--requests N] | figure9 | figure10 | figures (3/4/8)
//!   complexity                        FLOPs-vs-N scaling table
//!   all [--steps N]                   every table + figure in sequence
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use mita::coordinator::batcher::BatchPolicy;
use mita::coordinator::{
    serve, serve_model, serve_native, Engine, ModelServeConfig, NativeServeConfig, NetClient,
    NetServer, NetServerConfig, ReplicaPool, ReplicaPoolConfig, ServeConfig, Trainer,
    DEFAULT_MAX_INFLIGHT,
};
use mita::data::lra::{self, SeqTask};
use mita::data::rng::Rng;
use mita::data::{BatchSource, Split};
use mita::flops;
use mita::harness::tables::{self, Opts};
use mita::harness::{figures, train_bundle};
use mita::kernels::{
    dense_attention_mh, mita_attention_mh, MitaKernelConfig, MitaStats, Workspace, WorkspacePool,
    OP_ATTN_DENSE, OP_ATTN_MITA,
};
use mita::model::{MitaModel, ModelConfig, ModelScratch, OP_MODEL_INIT};
use mita::report::Table;
use mita::runtime::{BackendSpec, NativeAttnConfig, Runtime, Tensor};
use mita::service::{KernelId, QkvBatch, ServiceRequest};
use mita::train::{curve_json, loss_curve, AdamWConfig, NativeTrainer, TrainConfig};
use mita::util::cli;

const VALUED_FLAGS: &[&str] = &[
    "artifacts",
    "prefix",
    "steps",
    "seed",
    "checkpoint",
    "warm-start",
    "batches",
    "requests",
    "rate",
    "max-wait-ms",
    "queue-cap",
    "eval-batches",
    "log-every",
    // native-backend workload shape
    "n",
    "dim",
    "heads",
    "m",
    "k",
    "cap-factor",
    "block-q",
    "op",
    "max-batch",
    // native model subsystem
    "task",
    "seq-len",
    "vocab",
    "depth",
    // typed service front
    "listen",
    "addr-file",
    "workload",
    "addr",
    "binding",
    "max-inflight",
    "valid",
    "batch",
    "replicas",
    "retries",
    // streaming generation
    "prompt",
    "max-tokens",
    // tracing / observability
    "limit",
    "min-us",
    "trace-ring",
    "level",
    "log-level",
    // native training subsystem
    "lr",
    "kernel",
    "weight-decay",
    "clip",
    "eval-every",
    "checkpoint-out",
    "curve-out",
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, VALUED_FLAGS)?;
    let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
    let opts = Opts {
        steps: args.flag("steps").map(|s| s.parse()).transpose()?,
        seed: args.flag_parse("seed", 0i32)?,
    };

    match args.subcommand.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
        }
        "info" => {
            let rt = Runtime::load(&artifacts)?;
            let mut t =
                Table::new(&["bundle", "task", "attention", "N", "m", "k", "params", "artifacts"]);
            for name in rt.manifest().bundles_with_prefix(&args.flag_or("prefix", "")) {
                let b = rt.manifest().bundle(name)?;
                let mut arts: Vec<&str> = b.artifacts.keys().map(|s| s.as_str()).collect();
                arts.sort();
                t.row(&[
                    name.to_string(),
                    b.model.task.clone(),
                    b.model.attention.kind.clone(),
                    b.model.num_tokens().to_string(),
                    b.model.attention.m.to_string(),
                    b.model.attention.k.to_string(),
                    flops::param_count(&b.model).to_string(),
                    arts.join(","),
                ]);
            }
            print!("{}", t.render());
        }
        "flops" => {
            let rt = Runtime::load(&artifacts)?;
            let mut t = Table::new(&["bundle", "kind", "N", "attn FLOPs", "model FLOPs", "params"]);
            for name in rt.manifest().bundles_with_prefix(&args.flag_or("prefix", "")) {
                let b = rt.manifest().bundle(name)?;
                t.row(&[
                    name.to_string(),
                    b.model.attention.kind.clone(),
                    b.model.num_tokens().to_string(),
                    flops::gflops(flops::attention_flops(&b.model)),
                    flops::gflops(flops::model_flops(&b.model)),
                    flops::param_count(&b.model).to_string(),
                ]);
            }
            print!("{}", t.render());
        }
        "train" => {
            let bundle = args.positional(0, "bundle")?.to_string();
            let rt = Runtime::load(&artifacts)?;
            let warm = match args.flag("warm-start") {
                Some(p) => Some(mita::coordinator::checkpoint::load(std::path::Path::new(p))?),
                None => None,
            };
            let (trainer, oc) =
                train_bundle(&rt, &bundle, opts.seed, opts.steps, warm.as_deref())?;
            println!(
                "bundle={bundle} steps={} tail_loss={:.4} eval_loss={:.4} eval_acc={:.4}{} step_time={:.1}ms",
                oc.steps,
                oc.tail_loss,
                oc.eval.loss,
                oc.eval.accuracy,
                oc.eval.miou.map(|m| format!(" miou={m:.4}")).unwrap_or_default(),
                oc.mean_step_secs * 1e3,
            );
            println!("{}", figures::loss_curve_chart(&oc.loss_curve, &bundle));
            if let Some(path) = args.flag("checkpoint") {
                trainer.save_checkpoint(std::path::Path::new(path))?;
                println!("checkpoint saved to {path}");
            }
        }
        "eval" => {
            let bundle = args.positional(0, "bundle")?.to_string();
            let ckpt = PathBuf::from(args.positional(1, "checkpoint")?);
            let rt = Runtime::load(&artifacts)?;
            let ev = mita::coordinator::eval_checkpoint(
                &rt,
                &ckpt,
                &bundle,
                args.flag_parse("batches", 16usize)?,
            )?;
            println!(
                "bundle={bundle} eval_loss={:.4} eval_acc={:.4}{} ({} examples)",
                ev.loss,
                ev.accuracy,
                ev.miou.map(|m| format!(" miou={m:.4}")).unwrap_or_default(),
                ev.examples
            );
        }
        // One serving front over the typed service API: `serve <bundle>`
        // drives a compiled PJRT bundle, `--workload attn|model|generate`
        // the native backend, and `--listen ADDR` starts the network
        // server instead of the load generator.
        "serve" => {
            cmd_serve(&args, &artifacts, &opts)?;
        }
        "client" => {
            cmd_client(&args, &opts)?;
        }
        "table2" => {
            tables::table2(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table3" => {
            tables::table3(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table4" => {
            tables::table4(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table5" => {
            tables::table5(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table6" => {
            tables::table6(&Runtime::load(&artifacts)?, &opts)?;
        }
        "table7" => {
            tables::table7(&Runtime::load(&artifacts)?, &opts)?;
        }
        "complexity" => {
            tables::complexity_table(&Runtime::load(&artifacts)?)?;
        }
        "figure5" => {
            let rt = Runtime::load(&artifacts)?;
            figures::figure5(&artifacts, &rt, args.flag_parse("requests", 64usize)?)?;
        }
        "figure9" => {
            figures::figure9(&Runtime::load(&artifacts)?, opts.seed)?;
        }
        "figure10" => {
            figures::figure10(&Runtime::load(&artifacts)?, opts.seed)?;
        }
        "figures" => {
            let rt = Runtime::load(&artifacts)?;
            figures::figures34(&rt, opts.seed)?;
            figures::figure8(&rt, opts.seed)?;
        }
        "all" => {
            let rt = Runtime::load(&artifacts)?;
            tables::table2(&rt, &opts)?;
            tables::table3(&rt, &opts)?;
            tables::table4(&rt, &opts)?;
            tables::table5(&rt, &opts)?;
            tables::table6(&rt, &opts)?;
            tables::table7(&rt, &opts)?;
            tables::complexity_table(&rt)?;
            figures::figures34(&rt, opts.seed)?;
            figures::figure8(&rt, opts.seed)?;
            figures::figure9(&rt, opts.seed)?;
            figures::figure10(&rt, opts.seed)?;
            figures::figure5(&artifacts, &rt, args.flag_parse("requests", 64usize)?)?;
        }
        // ---- native backend (no artifacts required) -----------------------
        "native-check" => {
            let n = args.flag_parse("n", 256usize)?;
            let dim = args.flag_parse("dim", 64usize)?;
            let heads = args.flag_parse("heads", 4usize)?;
            anyhow::ensure!(
                heads >= 1 && dim % heads == 0,
                "--dim {dim} must divide into --heads {heads}"
            );
            let cfg = native_kernel_config(&args, n)?;
            let mut rng = Rng::new(opts.seed as u64);
            let mut gen =
                |len: usize| (0..len).map(|_| rng.range_f32(-2.0, 2.0)).collect::<Vec<f32>>();
            let (q, k, v) = (gen(n * dim), gen(n * dim), gen(n * dim));
            println!("simd_lane={} (override with MITA_SIMD)", mita::kernels::simd::active_lane());

            // 1) Degenerate full-attention parity: m = n, k = n must match
            //    the dense baseline exactly (within fp tolerance).
            let mut ws = Workspace::new();
            let pn = n.min(128);
            let pcfg = MitaKernelConfig { m: pn, k: pn, cap_factor: 2, block_q: 8 };
            let sub = pn * dim;
            let mut mita_out = vec![0.0f32; sub];
            let mut dense_out = vec![0.0f32; sub];
            let mut pstats = MitaStats::default();
            mita_attention_mh(
                &q[..sub],
                &k[..sub],
                &v[..sub],
                pn,
                heads,
                dim,
                &pcfg,
                &mut ws,
                &mut mita_out,
                &mut pstats,
            );
            dense_attention_mh(
                &q[..sub],
                &k[..sub],
                &v[..sub],
                pn,
                heads,
                dim,
                &mut ws,
                &mut dense_out,
            );
            let max_diff = mita_out
                .iter()
                .zip(&dense_out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let ok = max_diff < 1e-4;
            println!(
                "parity (n={pn}, m=k=n, heads={heads}): max|Δ| = {max_diff:.2e}  {}",
                if ok { "OK" } else { "FAIL" }
            );

            // 2) Configured MiTA vs dense on the full shape: timing + routing.
            let mut out = vec![0.0f32; n * dim];
            let mut stats = MitaStats::default();
            let t0 = Instant::now();
            mita_attention_mh(&q, &k, &v, n, heads, dim, &cfg, &mut ws, &mut out, &mut stats);
            let mita_secs = t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            dense_attention_mh(&q, &k, &v, n, heads, dim, &mut ws, &mut out);
            let dense_secs = t0.elapsed().as_secs_f64();
            println!(
                "n={n} dim={dim} heads={heads} m={} k={}: mita={:.2}ms dense={:.2}ms (x{:.2}) \
                 overflow={}/{} ({:.1}%) imbalance={:.2}",
                cfg.m,
                cfg.k,
                mita_secs * 1e3,
                dense_secs * 1e3,
                dense_secs / mita_secs,
                stats.overflow,
                stats.queries,
                stats.overflow_fraction() * 100.0,
                stats.load_imbalance(),
            );
            if !ok {
                bail!("native parity check failed (max|Δ| = {max_diff:.2e})");
            }
        }
        "model-check" => {
            let dim = args.flag_parse("dim", 32usize)?;
            let heads = args.flag_parse("heads", 2usize)?;
            let depth = args.flag_parse("depth", 2usize)?;
            let seq = args.flag_parse("seq-len", 64usize)?;
            anyhow::ensure!(
                heads >= 1 && dim % heads == 0,
                "--dim {dim} must divide into --heads {heads}"
            );
            let side = (seq as f64).sqrt() as usize;
            anyhow::ensure!(
                side * side == seq,
                "--seq-len {seq} must be a perfect square (image/pathfinder tasks)"
            );
            println!(
                "# model-check: dim={dim} heads={heads} depth={depth} seq_len={seq} simd_lane={}",
                mita::kernels::simd::active_lane()
            );
            let mut all_ok = true;
            for name in lra::TASK_NAMES {
                let (_, vocab) = lra_task_defaults(name)?;
                let task = lra::try_by_name(name, seq, vocab, opts.seed as u64)?;
                all_ok &= model_check_task(task.as_ref(), dim, heads, depth, opts.seed as u64)?;
            }
            if !all_ok {
                bail!("model-check failed (parity or checkpoint round-trip above)");
            }
        }
        "train-native" => {
            cmd_train_native(&args, &opts)?;
        }
        // Utility used by examples/tests to sanity-check one bundle quickly.
        "quickcheck" => {
            let rt = Runtime::load(&artifacts)?;
            let bundle = args.flag_or("prefix", "quickstart");
            let spec = rt.manifest().bundle(&bundle)?.clone();
            let source = BatchSource::for_bundle(&spec)?;
            let mut trainer = Trainer::new(&rt, &bundle, 0)?;
            trainer.train(&source, 5, 1)?;
            let ev = trainer.eval(&source, 2)?;
            println!("quickcheck {bundle}: loss={:.3} acc={:.3}", ev.loss, ev.accuracy);
        }
        other => bail!("unknown command {other:?} (try `mita help`)"),
    }
    Ok(())
}

/// The single serving front. Dispatch: `--listen` starts the network
/// server; otherwise the workload (bundle / attn / model, or `serve
/// <bundle>` for the PJRT path) runs under the load-generator benchmark
/// loop. All fronts produce typed `ServiceRequest` batches over the
/// same engine.
fn cmd_serve(args: &cli::Args, artifacts: &Path, opts: &Opts) -> Result<()> {
    // The --workload choice carries into --listen: a model workload must
    // bind its (default listops) model before the network server starts,
    // or every /v1/model/forward would be unbound_params. `generate` is
    // the same model workload, named for the streaming endpoint it
    // exists to serve (`/v1/generate` works under either name).
    let wants_model = matches!(args.flag("workload"), Some("model") | Some("generate"));
    if let Some(addr) = args.flag("listen") {
        return serve_listen(args, addr, opts, wants_model);
    }
    let workload = if args.positionals.first().is_some() {
        "bundle".to_string()
    } else {
        args.flag_or("workload", "attn")
    };
    match workload.as_str() {
        "bundle" => serve_bundle_front(args, artifacts),
        "attn" => serve_attn_front(args),
        "model" | "generate" => serve_model_front(args, opts),
        other => {
            bail!("unknown --workload {other:?} (expected bundle, attn, model, or generate)")
        }
    }
}

/// Generator front over a compiled PJRT bundle's `predict` artifact.
fn serve_bundle_front(args: &cli::Args, artifacts: &Path) -> Result<()> {
    let bundle = args.positional(0, "bundle")?.to_string();
    let rt = Runtime::load(artifacts)?;
    let spec = rt.manifest().bundle(&bundle)?.clone();
    let predict = rt.manifest().bundle_artifact(&bundle, "predict")?.to_string();
    let init = rt.manifest().bundle_artifact(&bundle, "init").map(str::to_string);
    drop(rt); // the engine thread owns its own runtime
    let engine = Engine::spawn(artifacts.to_path_buf(), vec![predict])?;
    // Bind weights: --checkpoint if given, else the init artifact.
    match args.flag("checkpoint") {
        Some(path) => {
            let params = mita::coordinator::checkpoint::load(std::path::Path::new(path))?;
            engine.handle().bind_tensors(&bundle, params)?;
        }
        None => {
            engine.handle().bind_init(&bundle, &init?, 0, spec.param_count())?;
        }
    }
    let cfg = ServeConfig {
        bundle: bundle.clone(),
        binding: bundle.clone(),
        requests: args.flag_parse("requests", 256usize)?,
        rate: args.flag_parse("rate", 0.0f64)?,
        queue_cap: args.flag_parse("queue-cap", 128usize)?,
        max_inflight: args.flag_parse("max-inflight", DEFAULT_MAX_INFLIGHT)?,
        policy: BatchPolicy {
            max_batch: spec.train.batch_size,
            max_wait: std::time::Duration::from_millis(args.flag_parse("max-wait-ms", 5u64)?),
        },
    };
    let report = serve(&engine.handle(), &spec, &bundle, &cfg)?;
    println!("{}", report.row());
    engine.shutdown();
    Ok(())
}

/// Build the native-backend spec for the raw attention workload from the
/// shared shape flags — the single construction path for the generator
/// front, `serve --listen`, and the replica pool, so none of them can
/// configure backends differently.
fn attn_backend_spec(args: &cli::Args) -> Result<(BackendSpec, usize, usize)> {
    let n = args.flag_parse("n", 1024usize)?;
    let dim = args.flag_parse("dim", 64usize)?;
    let heads = args.flag_parse("heads", 4usize)?;
    anyhow::ensure!(
        heads >= 1 && dim % heads == 0,
        "--dim {dim} must divide into --heads {heads}"
    );
    let mut attn = NativeAttnConfig::for_shape(n, dim, heads);
    attn.mita = native_kernel_config(args, n)?;
    Ok((BackendSpec::Native(attn), n, dim))
}

/// Spawn a native engine for the raw attention workload.
fn spawn_attn_engine(args: &cli::Args) -> Result<(Engine, usize, usize)> {
    let (spec, n, dim) = attn_backend_spec(args)?;
    Ok((Engine::spawn_backend(spec, vec![])?, n, dim))
}

/// Generator front over the native attention kernels.
fn serve_attn_front(args: &cli::Args) -> Result<()> {
    let (engine, n, dim) = spawn_attn_engine(args)?;
    let op = args.flag_or("op", "attn.mita");
    let cfg = NativeServeConfig {
        n,
        dim,
        op,
        requests: args.flag_parse("requests", 64usize)?,
        rate: args.flag_parse("rate", 0.0f64)?,
        queue_cap: args.flag_parse("queue-cap", 128usize)?,
        max_inflight: args.flag_parse("max-inflight", DEFAULT_MAX_INFLIGHT)?,
        policy: BatchPolicy {
            max_batch: args.flag_parse("max-batch", 8usize)?,
            max_wait: std::time::Duration::from_millis(args.flag_parse("max-wait-ms", 5u64)?),
        },
    };
    let report = serve_native(&engine.handle(), &cfg)?;
    println!("{}", report.row());
    engine.shutdown();
    Ok(())
}

/// Generator front over a whole native model serving LRA token traffic.
fn serve_model_front(args: &cli::Args, opts: &Opts) -> Result<()> {
    let task_name = args.flag_or("task", "listops");
    let (engine, task_name, task) = spawn_model_engine(args, opts, &task_name, "model")?;
    let cfg = ModelServeConfig {
        task: task_name,
        seq_len: task.seq_len(),
        vocab: task.vocab(),
        binding: "model".into(),
        requests: args.flag_parse("requests", 64usize)?,
        rate: args.flag_parse("rate", 0.0f64)?,
        queue_cap: args.flag_parse("queue-cap", 128usize)?,
        max_inflight: args.flag_parse("max-inflight", DEFAULT_MAX_INFLIGHT)?,
        policy: BatchPolicy {
            max_batch: args.flag_parse("max-batch", 8usize)?,
            max_wait: std::time::Duration::from_millis(args.flag_parse("max-wait-ms", 5u64)?),
        },
    };
    let report = serve_model(&engine.handle(), &cfg)?;
    println!("{}", report.row());
    engine.shutdown();
    Ok(())
}

/// Build the native-backend spec shaped for an LRA task (model +
/// matching raw-attention registry from the same kernel config).
fn model_backend_spec(
    args: &cli::Args,
    opts: &Opts,
    task_name: &str,
) -> Result<(BackendSpec, Box<dyn SeqTask>)> {
    let (def_n, def_vocab) = lra_task_defaults(task_name)?;
    let seq = args.flag_parse("seq-len", def_n)?;
    let vocab = args.flag_parse("vocab", def_vocab)?;
    let dim = args.flag_parse("dim", 64usize)?;
    let heads = args.flag_parse("heads", 4usize)?;
    let depth = args.flag_parse("depth", 2usize)?;
    anyhow::ensure!(
        heads >= 1 && dim % heads == 0,
        "--dim {dim} must divide into --heads {heads}"
    );
    let kernel = args.flag_or("op", "attn.mita");
    let task = lra::try_by_name(task_name, seq, vocab, opts.seed as u64)?;
    // One kernel config for both the model's MiTA blocks and the raw
    // attention registry, so the two can never drift apart.
    let kcfg = native_kernel_config(args, task.seq_len())?;
    let mut mcfg = ModelConfig::for_task(task.as_ref(), dim, heads, depth, &kernel);
    mcfg.mita = kcfg;
    let mut attn = NativeAttnConfig::for_shape(task.seq_len(), dim, heads).with_model(mcfg);
    attn.mita = kcfg;
    Ok((BackendSpec::Native(attn), task))
}

/// The model bind for a freshly spawned backend: `--checkpoint` params
/// if given (validated against the task geometry), else seeded init.
/// Returned as a typed request so it can target one engine or broadcast
/// through a [`ReplicaPool`].
fn model_bind_request(
    args: &cli::Args,
    opts: &Opts,
    binding: &str,
    task: &dyn SeqTask,
) -> Result<ServiceRequest> {
    match args.flag("checkpoint") {
        Some(path) => {
            let tensors = mita::coordinator::checkpoint::load(std::path::Path::new(path))?;
            // Fail at bind time, not mid-pipeline: the checkpoint's
            // self-describing config (the cheap leading descriptor
            // tensor — no need to parse the parameters here) must
            // fit the task geometry.
            anyhow::ensure!(!tensors.is_empty(), "checkpoint {path:?} is empty");
            let ckpt = ModelConfig::from_tensor(&tensors[0])?;
            anyhow::ensure!(
                ckpt.seq_len == task.seq_len(),
                "checkpoint seq_len {} != task seq_len {} (pass a matching --seq-len)",
                ckpt.seq_len,
                task.seq_len()
            );
            anyhow::ensure!(
                ckpt.vocab >= task.vocab(),
                "checkpoint vocab {} cannot embed task vocab {}",
                ckpt.vocab,
                task.vocab()
            );
            anyhow::ensure!(
                ckpt.classes == task.classes(),
                "checkpoint classes {} != task classes {}",
                ckpt.classes,
                task.classes()
            );
            Ok(ServiceRequest::BindCheckpoint { binding: binding.into(), params: tensors })
        }
        None => Ok(ServiceRequest::BindInit {
            binding: binding.into(),
            init_op: OP_MODEL_INIT.to_string(),
            seed: opts.seed,
            param_count: 0,
        }),
    }
}

/// Spawn a native engine shaped for an LRA task and bind the model
/// (checkpoint if `--checkpoint`, else seeded init) under `binding`.
fn spawn_model_engine(
    args: &cli::Args,
    opts: &Opts,
    task_name: &str,
    binding: &str,
) -> Result<(Engine, String, Box<dyn SeqTask>)> {
    let (spec, task) = model_backend_spec(args, opts, task_name)?;
    let engine = Engine::spawn_backend(spec, vec![])?;
    engine.handle().call(model_bind_request(args, opts, binding, task.as_ref())?)?;
    Ok((engine, task_name.to_string(), task))
}

/// `serve --listen ADDR`: the network front. `--replicas N` spawns N
/// native engine replicas from one spec behind least-outstanding routing
/// (see docs/SERVING.md); with `--task` / `--checkpoint` (or a model /
/// generate workload) a model is bound under `--binding` (default
/// "model") on **every** replica so `/v1/model/forward` and
/// `/v1/generate` are servable alongside `/v1/attention`.
/// `--trace-ring N` sizes the completed-request trace ring. `--addr-file
/// F` writes the bound address (useful with port 0 in scripts/CI). Runs
/// until a client posts `/v1/admin/shutdown`.
fn serve_listen(args: &cli::Args, addr: &str, opts: &Opts, wants_model: bool) -> Result<()> {
    // `--log-level` overrides the MITA_LOG env default for the process
    // journal (docs/OBSERVABILITY.md); parse before anything can emit.
    if let Some(name) = args.flag("log-level") {
        let level = mita::coordinator::Level::parse(name)
            .ok_or_else(|| anyhow::anyhow!("--log-level {name:?} wants debug|info|warn|error"))?;
        mita::coordinator::log::set_level(level);
    }
    let binding = args.flag_or("binding", "model");
    let replicas = args.flag_parse("replicas", 1usize)?;
    anyhow::ensure!(replicas >= 1, "--replicas {replicas} wants at least 1");
    let max_inflight = args.flag_parse("max-inflight", 64usize)?;
    let wants_model =
        wants_model || args.flag("task").is_some() || args.flag("checkpoint").is_some();
    let (spec, bind) = if wants_model {
        let task_name = args.flag_or("task", "listops");
        let (spec, task) = model_backend_spec(args, opts, &task_name)?;
        let bind = model_bind_request(args, opts, &binding, task.as_ref())?;
        (spec, Some(bind))
    } else {
        (attn_backend_spec(args)?.0, None)
    };
    // The transport cap is the pool-wide budget; each replica admits its
    // share, rounded up so the per-replica caps always cover it.
    let pool_cfg = ReplicaPoolConfig {
        replicas,
        max_inflight: max_inflight.div_ceil(replicas.max(1)).max(1),
        trace_capacity: args
            .flag_parse("trace-ring", ReplicaPoolConfig::default().trace_capacity)?,
        ..ReplicaPoolConfig::default()
    };
    let pool = Arc::new(ReplicaPool::spawn(spec, vec![], pool_cfg)?);
    if let Some(bind) = bind {
        pool.call(bind)?; // broadcasts to every replica
    }
    let cfg = NetServerConfig { addr: addr.to_string(), max_inflight };
    let server = NetServer::bind(pool.clone(), &cfg)?;
    let local = server.local_addr()?;
    println!(
        "serving on http://{local} (backend=native, replicas={replicas}, \
         protocol docs/PROTOCOL.md)"
    );
    if let Some(path) = args.flag("addr-file") {
        std::fs::write(path, local.to_string())?;
    }
    server.run()?;
    println!("shutdown complete");
    // Lingering keep-alive handler threads may still hold pool clones;
    // shut down explicitly when we hold the last one, otherwise engine
    // Drop impls clean up when those handlers exit.
    if let Ok(pool) = Arc::try_unwrap(pool) {
        pool.shutdown();
    }
    Ok(())
}

/// Loopback wire client: sends one typed request to a `serve --listen`
/// server and checks the response shape (exits non-zero on mismatch) —
/// the CI smoke step drives the full TCP round-trip with this.
fn cmd_client(args: &cli::Args, opts: &Opts) -> Result<()> {
    let addr = args.flag("addr").map(str::to_string);
    let addr = match (addr, args.flag("addr-file")) {
        (Some(a), _) => a,
        (None, Some(path)) => std::fs::read_to_string(path)?.trim().to_string(),
        (None, None) => bail!("client needs --addr HOST:PORT (or --addr-file F)"),
    };
    let client =
        NetClient::new(addr.as_str()).with_retries(args.flag_parse("retries", 0usize)?);
    match args.positional(0, "action")? {
        "health" => {
            client.healthz()?;
            println!("{addr}: ok");
        }
        "shutdown" => {
            client.shutdown()?;
            println!("{addr}: shutting down");
        }
        "stats" => {
            let stats =
                client.call(&ServiceRequest::Stats { reset: args.has("reset") })?.into_stats()?;
            let mita = stats
                .mita
                .map(|m| {
                    format!(
                        " mita: queries={} ovf={:.1}% imb={:.2}",
                        m.queries,
                        m.overflow_fraction() * 100.0,
                        m.load_imbalance()
                    )
                })
                .unwrap_or_default();
            println!(
                "executions={} execute_secs={:.3}{mita}",
                stats.runtime.executions, stats.runtime.execute_secs
            );
        }
        "attention" => {
            let n = args.flag_parse("n", 256usize)?;
            let dim = args.flag_parse("dim", 64usize)?;
            let batch = args.flag_parse("batch", 2usize)?;
            let valid = args.flag("valid").map(str::parse::<usize>).transpose()?;
            let op = KernelId::parse(&args.flag_or("op", "attn.mita"))?;
            let mut rng = Rng::new(opts.seed as u64);
            let data: Vec<f32> =
                (0..batch * 3 * n * dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let qkv = QkvBatch::fused(Tensor::f32(&[batch, 3, n, dim], data)?)?;
            let t0 = Instant::now();
            let out = client
                .call(&ServiceRequest::Attention { op: op.clone(), qkv, valid_rows: valid })?
                .into_tensor()?;
            anyhow::ensure!(
                out.shape() == [batch, n, dim],
                "attention response shape {:?} != [{batch}, {n}, {dim}]",
                out.shape()
            );
            println!(
                "attention {op}: out {:?} in {:.2}ms (round-trip)",
                out.shape(),
                t0.elapsed().as_secs_f64() * 1e3
            );
        }
        "model-forward" => {
            let task_name = args.flag_or("task", "listops");
            let (def_n, def_vocab) = lra_task_defaults(&task_name)?;
            let seq = args.flag_parse("seq-len", def_n)?;
            let vocab = args.flag_parse("vocab", def_vocab)?;
            let binding = args.flag_or("binding", "model");
            let task = lra::try_by_name(&task_name, seq, vocab, opts.seed as u64)?;
            let (tokens, _) = task.sample(Split::Val, 0);
            let tokens = Tensor::i32(&[1, task.seq_len()], tokens)?;
            let t0 = Instant::now();
            let logits = client
                .call(&ServiceRequest::ModelForward {
                    binding: binding.as_str().into(),
                    tokens,
                    valid_rows: None,
                })?
                .into_tensor()?;
            anyhow::ensure!(
                logits.shape().len() == 2 && logits.shape()[0] == 1,
                "model-forward response shape {:?} is not [1, classes]",
                logits.shape()
            );
            anyhow::ensure!(
                logits.as_f32()?.iter().all(|x| x.is_finite()),
                "model-forward returned non-finite logits"
            );
            println!(
                "model-forward {task_name}: logits {:?} in {:.2}ms (round-trip)",
                logits.shape(),
                t0.elapsed().as_secs_f64() * 1e3
            );
        }
        "generate" => {
            // Streamed decoding over /v1/generate: step chunk lines print
            // as they arrive, then the terminal response is checked
            // against the stream (token agreement + echoed trace_id) so
            // the CI smoke step exercises the full chunked round-trip.
            let binding = args.flag_or("binding", "model");
            let max_tokens = args.flag_parse("max-tokens", 8usize)?;
            let prompt: Vec<i32> = match args.flag("prompt") {
                Some(spec) => spec
                    .split(',')
                    .map(|t| {
                        t.trim()
                            .parse::<i32>()
                            .map_err(|e| anyhow::anyhow!("--prompt token {t:?}: {e}"))
                    })
                    .collect::<Result<_>>()?,
                None => vec![1, 2, 3, 4],
            };
            anyhow::ensure!(!prompt.is_empty(), "--prompt wants at least one token");
            let kernel = args.flag("kernel").map(KernelId::parse).transpose()?;
            let req = ServiceRequest::Generate {
                binding: binding.as_str().into(),
                prompt: Tensor::i32(&[prompt.len()], prompt)?,
                max_tokens,
                params: mita::service::GenerateParams { kernel },
            };
            let t0 = Instant::now();
            let mut steps = Vec::new();
            let (resp, trace_id) = client.generate(&req, &mut |ev| {
                println!(
                    "  step {} token={} latency={}us",
                    ev.index,
                    ev.token,
                    ev.latency_ns / 1_000
                );
                steps.push(ev);
            })?;
            let (tokens, prefill) = match resp {
                mita::service::ServiceResponse::Generate { tokens, prefill_tokens } => {
                    (tokens, prefill_tokens)
                }
                other => bail!("unexpected generate response {other:?}"),
            };
            let toks = tokens.as_i32()?.to_vec();
            anyhow::ensure!(
                steps.len() == toks.len(),
                "streamed {} steps but the terminal response carries {} tokens",
                steps.len(),
                toks.len()
            );
            anyhow::ensure!(
                steps.iter().map(|e| e.token).eq(toks.iter().copied()),
                "streamed tokens diverge from the terminal response"
            );
            anyhow::ensure!(trace_id.is_some(), "terminal response did not echo a trace_id");
            println!(
                "generate: {prefill} prompt tokens -> {} new in {:.2}ms (trace #{}) tokens={toks:?}",
                toks.len(),
                t0.elapsed().as_secs_f64() * 1e3,
                trace_id.unwrap_or(0),
            );
        }
        "trace" => {
            // Raw wire text through the JSON parser, so the CI smoke
            // exercises the exact exported schema (see
            // docs/OBSERVABILITY.md for the field reference).
            let limit = args.flag("limit").map(str::parse::<usize>).transpose()?;
            let min_us = args.flag("min-us").map(str::parse::<u64>).transpose()?;
            let raw = client.trace_raw(limit, min_us)?;
            if args.has("json") {
                println!("{raw}");
                return Ok(());
            }
            let body = mita::util::json::Value::parse(&raw)?;
            let traces = body.get("traces")?.as_arr()?;
            println!(
                "{} trace(s) retained (ring capacity={} pushed={})",
                traces.len(),
                body.get("capacity")?.as_f64()? as u64,
                body.get("pushed")?.as_f64()? as u64,
            );
            for t in traces {
                let spans = t.get("spans")?;
                let us = |key: &str| -> Result<f64> { spans.get(key)?.as_f64() };
                println!(
                    "  #{} {} replica={} depth={} ok={} total={:.1}us \
                     (admission={:.1} route={:.1} queue={:.1} batch={:.1} execute={:.1} \
                     decode={:.1}) blocks={}",
                    t.get("trace_id")?.as_f64()? as u64,
                    t.get("kind")?.as_str()?,
                    t.get("replica")?.as_f64()? as u64,
                    t.get("queue_depth")?.as_f64()? as u64,
                    t.get("ok")?.as_bool()?,
                    us("total_us")?,
                    us("admission_us")?,
                    us("route_us")?,
                    us("queue_us")?,
                    us("batch_us")?,
                    us("execute_us")?,
                    us("decode_us")?,
                    t.get("blocks")?.as_arr()?.len(),
                );
            }
        }
        "check-prometheus" => {
            // Fetch the text exposition and run the in-repo grammar +
            // coverage checker over it (non-zero exit on violations) —
            // the CI smoke's guard that the Prometheus surface stays
            // scrapeable.
            let text = client.metrics_prometheus()?;
            let samples = mita::coordinator::check_prometheus_text(&text)
                .map_err(|e| anyhow::anyhow!("prometheus exposition invalid: {e}"))?;
            println!("{addr}: prometheus exposition ok ({samples} samples)");
        }
        "metrics" => {
            // Probe the raw wire text first so a renamed series fails CI
            // even if the typed decoder were updated in lockstep; then
            // print the typed summary.
            let raw = client.metrics_raw()?;
            let missing: Vec<&str> = mita::coordinator::metrics::METRIC_NAMES
                .iter()
                .copied()
                .filter(|name| !raw.contains(name))
                .collect();
            anyhow::ensure!(
                missing.is_empty(),
                "/v1/metrics is missing documented series {missing:?} (see docs/SERVING.md)"
            );
            let m = client.metrics()?;
            let lat = &m.request_latency_us;
            println!(
                "build={} git={} uptime={:.0}s simd_lane={}",
                m.build_version, m.build_git, m.uptime_seconds, m.simd_lane,
            );
            println!(
                "requests={} shed={} errors={} shed_fraction={:.4} \
                 p50={:.0}us p95={:.0}us p99={:.0}us",
                m.serve_requests_total,
                m.serve_shed_total,
                m.serve_errors_total,
                m.shed_fraction(),
                lat.p50_us,
                lat.p95_us,
                lat.p99_us,
            );
            for w in &m.slo.windows {
                println!(
                    "  slo {}: requests={} errors={} slow={} error_burn={:.2} latency_burn={:.2}",
                    w.window, w.requests, w.errors, w.slow, w.error_burn_rate,
                    w.latency_burn_rate,
                );
            }
            for r in &m.replicas {
                println!(
                    "  replica {}: health={} requests={} depth={}/{} ovf={:.1}% imb={:.2}",
                    r.replica,
                    r.health,
                    r.replica_requests_total,
                    r.replica_queue_depth,
                    r.max_inflight,
                    r.overflow_fraction * 100.0,
                    r.load_imbalance,
                );
            }
        }
        "readyz" => {
            // Unlike `health` (process liveness), readyz answers whether
            // the pool can still route: 503 once every replica is
            // unhealthy. The exit code follows the HTTP status so CI
            // probes can gate on it directly.
            let (status, body) = client.readyz_raw()?;
            let v = mita::util::json::Value::parse(&body)?;
            println!(
                "{addr}: {} (HTTP {status}) replicas healthy={} degraded={} unhealthy={}",
                v.get("status")?.as_str()?,
                v.get("replicas_healthy")?.as_f64()? as u64,
                v.get("replicas_degraded")?.as_f64()? as u64,
                v.get("replicas_unhealthy")?.as_f64()? as u64,
            );
            anyhow::ensure!(status == 200, "{addr}: not ready (HTTP {status})");
        }
        "logs" => {
            // GET /v1/logs: the structured event journal, newest first
            // ([--limit N] [--level debug|info|warn|error]; --json dumps
            // the raw wire body for scripts).
            let limit = args.flag("limit").map(str::parse::<usize>).transpose()?;
            let raw = client.logs_raw(limit, args.flag("level"))?;
            if args.has("json") {
                println!("{raw}");
                return Ok(());
            }
            let body = mita::util::json::Value::parse(&raw)?;
            let events = body.get("events")?.as_arr()?;
            println!(
                "{} event(s) retained (ring capacity={} pushed={} level={})",
                events.len(),
                body.get("capacity")?.as_f64()? as u64,
                body.get("pushed")?.as_f64()? as u64,
                body.get("level")?.as_str()?,
            );
            for e in events {
                let trace = match e.opt("trace_id") {
                    Some(t) => format!(" trace=#{}", t.as_f64()? as u64),
                    None => String::new(),
                };
                println!(
                    "  #{} [{}] {} unix_ms={}{}: {}",
                    e.get("seq")?.as_f64()? as u64,
                    e.get("level")?.as_str()?,
                    e.get("event")?.as_str()?,
                    e.get("unix_ms")?.as_f64()? as u64,
                    trace,
                    e.get("message")?.as_str()?,
                );
            }
        }
        "profile" => {
            // GET /v1/profile: the continuous op-level timing tree
            // (per-kernel phase accumulators; --json dumps the raw body).
            let raw = client.profile_raw()?;
            if args.has("json") {
                println!("{raw}");
                return Ok(());
            }
            let body = mita::util::json::Value::parse(&raw)?;
            println!("uptime={:.0}s", body.get("uptime_seconds")?.as_f64()?);
            let tree = body.get("profile")?.as_obj()?;
            let mut groups: Vec<&String> = tree.keys().collect();
            groups.sort();
            for group in groups {
                let node = tree.get(group.as_str()).expect("key from iteration");
                println!("  {group}: total={:.1}us", node.get("total_us")?.as_f64()?);
                let leaves = node.as_obj()?;
                let mut names: Vec<&String> = leaves.keys().collect();
                names.sort();
                for name in names {
                    if name == "total_us" {
                        continue;
                    }
                    let leaf = leaves.get(name.as_str()).expect("key from iteration");
                    println!(
                        "    {name}: time={:.1}us calls={} mean={:.1}us",
                        leaf.get("time_us")?.as_f64()?,
                        leaf.get("calls")?.as_f64()? as u64,
                        leaf.get("mean_us")?.as_f64()?,
                    );
                }
            }
        }
        other => {
            bail!(
                "unknown client action {other:?} \
                 (health|readyz|attention|model-forward|generate|stats|metrics|trace|logs|\
                  profile|check-prometheus|shutdown)"
            )
        }
    }
    Ok(())
}

/// `train-native`: end-to-end native training on an LRA task — exact
/// backward passes + AdamW over the pure-Rust model, periodic eval,
/// best-checkpoint save through the shared container format. No
/// artifacts, no Python. `--assert-improved` exits non-zero unless the
/// tail loss beats the first step's loss (the CI smoke gate).
fn cmd_train_native(args: &cli::Args, opts: &Opts) -> Result<()> {
    let task_name = args.flag_or("task", "listops");
    let (def_n, def_vocab) = lra_task_defaults(&task_name)?;
    let seq = args.flag_parse("seq-len", def_n)?;
    let vocab = args.flag_parse("vocab", def_vocab)?;
    let dim = args.flag_parse("dim", 32usize)?;
    let heads = args.flag_parse("heads", 2usize)?;
    let depth = args.flag_parse("depth", 2usize)?;
    anyhow::ensure!(
        heads >= 1 && dim % heads == 0,
        "--dim {dim} must divide into --heads {heads}"
    );
    let kernel = match args.flag_or("kernel", "mita").as_str() {
        "mita" | OP_ATTN_MITA => OP_ATTN_MITA,
        "dense" | OP_ATTN_DENSE => OP_ATTN_DENSE,
        other => bail!("--kernel {other:?} (expected mita or dense)"),
    };
    let steps = args.flag_parse("steps", 100usize)?;
    let batch = args.flag_parse("batch", 8usize)?;
    let optim = AdamWConfig {
        lr: args.flag_parse("lr", 1e-2f64)?,
        weight_decay: args.flag_parse("weight-decay", 0.01f64)?,
        grad_clip: args.flag_parse("clip", 1.0f64)?,
        ..AdamWConfig::default()
    };
    let task = lra::try_by_name(&task_name, seq, vocab, opts.seed as u64)?;
    let mut mcfg = ModelConfig::for_task(task.as_ref(), dim, heads, depth, kernel);
    mcfg.mita = native_kernel_config(args, seq)?;
    let model = MitaModel::init(mcfg, opts.seed as u64)?;
    let pcount = model.cfg.param_count();
    let mut trainer = NativeTrainer::new(model, optim, opts.seed as u64)?;
    let run = TrainConfig {
        steps,
        batch,
        eval_every: args.flag_parse("eval-every", 25usize)?,
        eval_batches: args.flag_parse("eval-batches", 4usize)?,
        log_every: args.flag_parse("log-every", 10usize)?,
        checkpoint: args.flag("checkpoint-out").map(PathBuf::from),
    };
    println!(
        "# train-native: task={task_name} n={seq} dim={dim} heads={heads} depth={depth} \
         kernel={kernel} steps={steps} batch={batch} lr={} params={pcount}",
        optim.lr
    );
    let outcome = trainer.train(task.as_ref(), &run)?;
    let stats = trainer.mita_stats();
    println!(
        "steps={} first_loss={:.4} final_loss={:.4} tail_loss={:.4} eval_loss={:.4} \
         eval_acc={:.4} best_eval_loss={:.4} step_time={:.1}ms steps/s={:.2} ovf={:.1}%",
        outcome.steps,
        outcome.first_loss,
        outcome.final_loss,
        outcome.tail_loss,
        outcome.final_eval.loss,
        outcome.final_eval.accuracy,
        outcome.best_eval.loss,
        outcome.mean_step_secs * 1e3,
        1.0 / outcome.mean_step_secs.max(1e-9),
        stats.overflow_fraction() * 100.0,
    );
    let chart_name = format!("train-native/{task_name}");
    println!("{}", figures::loss_curve_chart(&loss_curve(&trainer.history), &chart_name));
    if let Some(path) = args.flag("checkpoint-out") {
        println!("best checkpoint saved to {path}");
    }
    if let Some(path) = args.flag("curve-out") {
        std::fs::write(path, curve_json(&trainer.history))?;
        println!("loss curve written to {path}");
    }
    if args.has("assert-improved") {
        anyhow::ensure!(
            outcome.tail_loss < outcome.first_loss,
            "training did not improve: tail loss {:.4} >= first loss {:.4}",
            outcome.tail_loss,
            outcome.first_loss
        );
        println!(
            "loss improved: {:.4} -> {:.4} (tail mean)",
            outcome.first_loss, outcome.tail_loss
        );
    }
    Ok(())
}

/// MiTA kernel parameters from CLI flags, defaulting to the paper-flavored
/// shape for the sequence length.
fn native_kernel_config(args: &cli::Args, n: usize) -> Result<MitaKernelConfig> {
    let auto = MitaKernelConfig::for_seq(n);
    Ok(MitaKernelConfig {
        m: args.flag_parse("m", auto.m)?,
        k: args.flag_parse("k", auto.k)?,
        cap_factor: args.flag_parse("cap-factor", auto.cap_factor)?,
        block_q: args.flag_parse("block-q", auto.block_q)?,
    })
}

/// Default (seq_len, vocab) per LRA task for the model CLI commands
/// (vocab comes from the canonical `lra::default_vocab` table).
fn lra_task_defaults(name: &str) -> Result<(usize, usize)> {
    match lra::default_vocab(name) {
        Some(vocab) => Ok((256, vocab)),
        None => bail!("unknown LRA task {name:?} (expected one of {:?})", lra::TASK_NAMES),
    }
}

/// One LRA task's model-level checks: MiTA-vs-dense logits parity on the
/// landmarks-cover-everything config (m = k = n), real-config timing +
/// routing stats, and a checkpoint save/load round-trip. Prints one row;
/// returns whether every check passed.
fn model_check_task(
    task: &dyn SeqTask,
    dim: usize,
    heads: usize,
    depth: usize,
    seed: u64,
) -> Result<bool> {
    let n = task.seq_len();
    let bsz = 2usize;
    let (tokens, _) = lra::batch_host(task, Split::Val, 0, bsz);
    let pool = WorkspacePool::new();
    let mut scratch = ModelScratch::default();
    let mut stats = MitaStats::default();

    // 1) Parity: with m = k = n every expert gathers the full KV set, so
    //    MiTA blocks must reproduce dense blocks within fp tolerance.
    let pcfg = MitaKernelConfig { m: n, k: n, cap_factor: 2, block_q: 8 };
    let cfg = ModelConfig::for_task(task, dim, heads, depth, OP_ATTN_MITA).with_mita(pcfg);
    let pmodel = MitaModel::init(cfg, seed)?;
    let pregistry = pmodel.registry();
    let lm = pmodel.forward(&tokens, bsz, bsz, &pregistry, &pool, &mut scratch, &mut stats)?;
    let pdense = pmodel.with_kernel(OP_ATTN_DENSE)?;
    let ld = pdense.forward(&tokens, bsz, bsz, &pregistry, &pool, &mut scratch, &mut stats)?;
    let max_diff = lm.iter().zip(&ld).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    let parity_ok = max_diff < 1e-4;

    // 2) Real config: timing + routing stats, MiTA vs dense blocks.
    let cfg = ModelConfig::for_task(task, dim, heads, depth, OP_ATTN_MITA);
    let model = MitaModel::init(cfg, seed)?;
    let registry = model.registry();
    let dense = model.with_kernel(OP_ATTN_DENSE)?;
    stats.reset();
    let t0 = Instant::now();
    let logits = model.forward(&tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)?;
    let mita_secs = t0.elapsed().as_secs_f64();
    let ovf = stats.overflow_fraction();
    let t0 = Instant::now();
    dense.forward(&tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)?;
    let dense_secs = t0.elapsed().as_secs_f64();

    // 3) Checkpoint round-trip: the reloaded model must agree bit-for-bit.
    let dir = std::env::temp_dir().join(format!("mita_model_check_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{}.ckpt", task.name()));
    model.save(&path)?;
    let loaded = MitaModel::load(&path)?;
    let lr = loaded.forward(&tokens, bsz, bsz, &registry, &pool, &mut scratch, &mut stats)?;
    let roundtrip_ok = lr == logits && loaded.cfg == model.cfg;
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok(); // non-recursive: only removes once empty

    println!(
        "{:10} n={n:4} parity max|Δ|={max_diff:.2e} [{}]  mita={:7.2}ms dense={:7.2}ms (x{:.2}) \
         ovf={:4.1}%  ckpt roundtrip [{}]",
        task.name(),
        if parity_ok { "OK" } else { "FAIL" },
        mita_secs * 1e3,
        dense_secs * 1e3,
        dense_secs / mita_secs,
        ovf * 100.0,
        if roundtrip_ok { "OK" } else { "FAIL" },
    );
    Ok(parity_ok && roundtrip_ok)
}

const HELP: &str = r#"mita — MiTA attention coordinator (rust + JAX/Pallas AOT)

usage: mita [--artifacts DIR] <command> [args]

inspection:
  info [--prefix P]        list bundles from the manifest
  flops [--prefix P]       analytical FLOPs/params per bundle
  complexity               attention FLOPs scaling vs N

single runs:
  train <bundle> [--steps N] [--seed S] [--checkpoint F] [--warm-start F]
  eval <bundle> <checkpoint> [--batches N]

serving (one typed-request front; see docs/PROTOCOL.md + docs/SERVING.md):
  serve <bundle> [--requests N] [--rate R] [--max-wait-ms W] [--queue-cap C]
           load-generator benchmark over a compiled PJRT bundle
  serve --workload attn|model|generate [--op attn.mita|attn.dense] [--task T] ...
           same benchmark over the native backend (model and generate
           both bind a native model; generate names the streaming path)
  serve --listen ADDR [--replicas N] [--addr-file F] [--max-inflight C]
        [--task T [--seq-len N] [--dim D] [--heads H] [--depth L]]
        [--checkpoint F] [--binding K] [--trace-ring N]
        [--log-level debug|info|warn|error]
           network front: TCP HTTP/1.1 + JSON over the typed service API
           (/v1/attention, /v1/model/forward, /v1/generate, /v1/bind,
           /v1/stats, /v1/metrics, ...); --replicas N routes across N
           engine replicas with least-outstanding routing + typed
           shedding; --trace-ring N sizes the completed-request trace
           ring (default 256, floor 16); runs until a client posts
           /v1/admin/shutdown
  client (--addr HOST:PORT | --addr-file F)
         <health|readyz|attention|model-forward|generate|stats|metrics|
          trace|logs|profile|check-prometheus|shutdown>
         [--retries N] [--n N] [--dim D] [--batch B] [--valid V]
         [--task T] [--binding K] [--limit N] [--min-us T] [--level L] [--json]
         [--prompt T1,T2,...] [--max-tokens N] [--kernel attn.mita|attn.dense]
           loopback wire client: sends one typed request and asserts the
           response shape (non-zero exit on protocol errors); metrics
           asserts every documented /v1/metrics series is present and
           prints build info, uptime, SLO burn rates, and per-replica
           health; readyz probes GET /v1/readyz (exit follows the HTTP
           status: 200 while any replica can route, else 503);
           generate streams /v1/generate decode steps (chunked transfer
           encoding) and checks the terminal response against the
           stream (docs/DECODE.md);
           trace prints GET /v1/trace stage spans + per-block profiles
           ([--limit N] [--min-us T] [--json]; docs/OBSERVABILITY.md);
           logs prints the GET /v1/logs structured event journal
           ([--limit N] [--level debug|info|warn|error] [--json]);
           profile prints the GET /v1/profile op-level timing tree
           ([--json]);
           check-prometheus validates /v1/metrics?format=prometheus
           with the in-repo grammar + coverage checker;
           --retries N retries overloaded sheds per the server's
           retry_after_ms hint

native backend (pure-Rust kernels, no artifacts or Python needed):
  native-check [--n N] [--dim D] [--heads H] [--m M] [--k K] [--cap-factor C]
           parity vs dense attention + single-shot speedup/routing stats

native model subsystem (full MiTA transformer over the kernel registry):
  model-check [--seq-len N] [--dim D] [--heads H] [--depth L] [--seed S]
           per-LRA-task checks: MiTA-vs-dense logits parity (m = k = n),
           forward timing + routing stats, checkpoint round-trip

native training (exact backward passes + AdamW; see docs/TRAINING.md):
  train-native [--task T] [--seq-len N] [--dim D] [--heads H] [--depth L]
               [--steps N] [--batch B] [--lr X] [--weight-decay W] [--clip C]
               [--kernel mita|dense] [--eval-every E] [--eval-batches B]
               [--checkpoint-out F] [--curve-out F] [--assert-improved]
           trains a native MiTA transformer on an LRA task end to end;
           the best-eval checkpoint reloads unchanged into serve
           --workload model / model-check / the network front

paper reproduction (see DESIGN.md experiment index):
  table2   from-scratch image classification (attention varied only)
  table3   model-level comparison
  table4   dense prediction (mIoU + FLOPs reduction)
  table5   synthetic LRA benchmark (acc + train throughput)
  table6   ablations (landmarks, m x k, compress/route)
  table7   finetuning pretrained standard-attn params
  figure5  inference throughput vs N (serving benchmark)
  figure9  train-with-X / infer-with-Y generalization matrix
  figure10 (m, k) generalization grid
  figures  figures 3/4 (expert heatmaps) + 8 (overlap)
  all      everything above in sequence
"#;
