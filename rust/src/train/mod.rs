//! Native training subsystem: exact reverse-mode gradients for the
//! MiTA transformer, an AdamW optimizer, and an end-to-end LRA training
//! loop — pure Rust, no PJRT artifacts, no Python.
//!
//! The subsystem closes the train → checkpoint → serve loop natively:
//!
//! - [`backward`]: hand-derived layer adjoints (matmul/bias, LayerNorm,
//!   GELU, softmax cross-entropy) plus both attention backwards — the
//!   exact O(n²) dense softmax backward and the MiTA backward, which
//!   recomputes the forward's landmark pooling, top-k picks, and argmax
//!   routing bit-identically and treats those selections as constants
//!   (straight-through), while gradients flow exactly through each
//!   query's softmax over its expert's gathered KV pairs.
//! - [`grads`]: the flat [`Gradients`] buffer in [`ModelParams`]'
//!   checkpoint order, with named per-tensor views and the matching
//!   parameter walk the optimizer zips against.
//! - [`model_grad`]: per-example tape forward + reverse sweep, fanned
//!   out over examples with a fixed-order gradient reduction — loss
//!   curves are bit-identical across `MITA_NUM_THREADS`.
//! - [`optim`]: [`AdamW`] with bias correction, decoupled weight decay,
//!   and global-norm gradient clipping.
//! - [`trainer`]: [`NativeTrainer`] — deterministic minibatch streams
//!   over the LRA [`SeqTask`]s, periodic eval through the *inference*
//!   forward, best-checkpoint saves through
//!   [`crate::coordinator::checkpoint`].
//! - [`gradcheck`]: central-difference checking used by the test suite
//!   to pin every analytic gradient against numeric derivatives.
//!
//! The PJRT-artifact training driver ([`crate::coordinator::trainer`])
//! is unchanged and independent; this module is the native counterpart.
//! Derivation sketches and conventions: `docs/TRAINING.md`.
//!
//! [`ModelParams`]: crate::model::ModelParams
//! [`SeqTask`]: crate::data::lra::SeqTask

pub mod backward;
pub mod gradcheck;
pub mod grads;
pub mod model_grad;
pub mod optim;
pub mod trainer;

pub use backward::AttnKind;
pub use grads::Gradients;
pub use model_grad::{loss_and_gradients, BatchOutcome, TrainScratch};
pub use optim::{AdamW, AdamWConfig};
pub use trainer::{curve_json, json_num, loss_curve, NativeTrainer, TrainConfig, TrainOutcome};
