//! Hand-derived exact backward passes for every layer primitive in the
//! native MiTA transformer.
//!
//! Conventions mirror the forward stack: everything is f32, row-major,
//! serial, and allocation-free over a [`Workspace`] — parallelism lives
//! one level up (per-example data parallelism in
//! [`crate::train::model_grad`]). A `d*` buffer that is *overwritten* is
//! documented as such; gradient buffers for parameters always
//! *accumulate* (`+=`), because one example touches each parameter tensor
//! once but the per-example gradients later sum across the batch.
//!
//! The MiTA backward follows the **straight-through selection**
//! convention: landmark pooling, top-k KV selection, and argmax routing
//! are recomputed with the forward's own selection helper
//! ([`crate::kernels::mita::select_experts`] — one function, so the two
//! sides cannot drift; bit-identical indices) and then *treated as
//! constants* — gradients flow through the gathered KV pairs and the
//! per-expert softmax exactly, and not through the selection logits.
//! Capacity packing never enters the backward at all: packed and
//! overflow-fallback queries compute the same expert attention in the
//! forward, so their gradients are the same expression too.

use crate::kernels::linalg::{axpy, dot, gather_head, scatter_head};
use crate::kernels::mita::MitaKernelConfig;
use crate::kernels::simd;
use crate::kernels::workspace::Workspace;
use crate::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};
use crate::model::transformer::LN_EPS;

// ---------------------------------------------------------------------------
// Matmul adjoints
// ---------------------------------------------------------------------------

/// `out[i, j] = Σ_t a[i, t] · b[t, j]` for row-major `a [p, q]`,
/// `b [q, r]` — the adjoint of [`crate::kernels::linalg::matmul_nt`]
/// with respect to its first operand (`dx = dy · W`). Overwrites `out`.
pub fn matmul_nn(a: &[f32], b: &[f32], p: usize, q: usize, r: usize, out: &mut [f32]) {
    assert_eq!(a.len(), p * q, "a must be [p, q]");
    assert_eq!(b.len(), q * r, "b must be [q, r]");
    assert_eq!(out.len(), p * r, "out must be [p, r]");
    out.fill(0.0);
    matmul_nn_acc(a, b, p, q, r, out);
}

/// [`matmul_nn`] that accumulates (`out += a · b`) instead of
/// overwriting — used to sum the Q/K/V input-gradient contributions.
pub fn matmul_nn_acc(a: &[f32], b: &[f32], p: usize, q: usize, r: usize, out: &mut [f32]) {
    assert_eq!(a.len(), p * q, "a must be [p, q]");
    assert_eq!(b.len(), q * r, "b must be [q, r]");
    assert_eq!(out.len(), p * r, "out must be [p, r]");
    for (arow, orow) in a.chunks_exact(q).zip(out.chunks_exact_mut(r)) {
        for (&av, brow) in arow.iter().zip(b.chunks_exact(r)) {
            axpy(av, brow, orow);
        }
    }
}

/// `out[j, c] += Σ_i a[i, j] · b[i, c]` for row-major `a [n, q]`,
/// `b [n, r]` — Aᵀ·B, the weight-gradient shape of every linear layer
/// (`dW += dyᵀ · x`). Accumulates into `out [q, r]`.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], n: usize, q: usize, r: usize, out: &mut [f32]) {
    assert_eq!(a.len(), n * q, "a must be [n, q]");
    assert_eq!(b.len(), n * r, "b must be [n, r]");
    assert_eq!(out.len(), q * r, "out must be [q, r]");
    for (arow, brow) in a.chunks_exact(q).zip(b.chunks_exact(r)) {
        for (&av, orow) in arow.iter().zip(out.chunks_exact_mut(r)) {
            axpy(av, brow, orow);
        }
    }
}

/// `db += Σ_rows dy[row, :]` — the bias gradient of a linear layer.
pub fn bias_grad_acc(dy: &[f32], db: &mut [f32]) {
    assert_eq!(dy.len() % db.len(), 0, "dy must be [rows, len(db)]");
    for row in dy.chunks_exact(db.len()) {
        for (acc, &v) in db.iter_mut().zip(row) {
            *acc += v;
        }
    }
}

// ---------------------------------------------------------------------------
// LayerNorm / GELU / softmax cross-entropy
// ---------------------------------------------------------------------------

/// Forward twin of [`layer_norm_backward`]: delegates to the model's own
/// `layer_norm_rows`, so gradient checks differentiate exactly the math
/// inference runs.
pub fn layer_norm_forward(x: &[f32], d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    crate::model::transformer::layer_norm_rows(x, d, g, b, out);
}

/// Forward twin of [`gelu_backward`] (the model's `gelu_in_place`).
pub fn gelu_forward(x: &mut [f32]) {
    crate::model::transformer::gelu_in_place(x);
}

/// Backward of `layer_norm_rows` over `[rows, d]` input `x` with scale
/// `g`: writes `dx` (overwritten) and accumulates `dg` / `db`. The mean
/// and variance are recomputed from `x` with the forward's expression
/// order, so `x̂` is bit-identical to the forward pass.
pub fn layer_norm_backward(
    x: &[f32],
    d: usize,
    g: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    assert_eq!(x.len(), dy.len());
    assert_eq!(x.len(), dx.len());
    assert_eq!(x.len() % d, 0);
    assert_eq!(g.len(), d);
    assert_eq!(dg.len(), d);
    assert_eq!(db.len(), d);
    let ops = simd::ops();
    for ((xrow, dyrow), dxrow) in
        x.chunks_exact(d).zip(dy.chunks_exact(d)).zip(dx.chunks_exact_mut(d))
    {
        let mean = (ops.sum)(xrow) / d as f32;
        let var = (ops.sq_dev_sum)(xrow, mean) / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        // a = dy·g (the x̂-gradient); s1 = mean(a), s2 = mean(a·x̂).
        let mut s1 = 0.0f32;
        let mut s2 = 0.0f32;
        for ((&xv, &dyv), (&gc, (dgc, dbc))) in
            xrow.iter().zip(dyrow).zip(g.iter().zip(dg.iter_mut().zip(db.iter_mut())))
        {
            let xhat = (xv - mean) * inv;
            let a = dyv * gc;
            s1 += a;
            s2 += a * xhat;
            *dgc += dyv * xhat;
            *dbc += dyv;
        }
        s1 /= d as f32;
        s2 /= d as f32;
        for ((&xv, &dyv), (&gc, dxc)) in
            xrow.iter().zip(dyrow).zip(g.iter().zip(dxrow.iter_mut()))
        {
            let xhat = (xv - mean) * inv;
            *dxc = (dyv * gc - s1 - xhat * s2) * inv;
        }
    }
}

/// Backward of the tanh-approximation GELU: `dx = dy · gelu'(x)`,
/// element-wise (overwrites `dx`). Constants match `gelu_in_place`.
pub fn gelu_backward(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    assert_eq!(x.len(), dy.len());
    assert_eq!(x.len(), dx.len());
    const C: f32 = 0.797_884_6; // sqrt(2/π), as in the forward
    const A: f32 = 0.044_715;
    for ((&u, &dyv), dxv) in x.iter().zip(dy).zip(dx.iter_mut()) {
        let t = (C * (u + A * u * u * u)).tanh();
        let dinner = C * (1.0 + 3.0 * A * u * u);
        let dgelu = 0.5 * (1.0 + t) + 0.5 * u * (1.0 - t * t) * dinner;
        *dxv = dyv * dgelu;
    }
}

/// Softmax cross-entropy of one logit row against an integer label:
/// returns the loss `−log softmax(logits)[label]` (computed in f64) and
/// writes `dlogits = softmax(logits) − onehot(label)` (overwritten).
pub fn softmax_xent(logits: &[f32], label: usize, dlogits: &mut [f32]) -> f64 {
    assert_eq!(logits.len(), dlogits.len());
    assert!(label < logits.len(), "label {label} outside {} classes", logits.len());
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut den = 0.0f64;
    for (&l, d) in logits.iter().zip(dlogits.iter_mut()) {
        let e = ((l as f64) - mx).exp();
        den += e;
        *d = e as f32; // unnormalized for now
    }
    let inv = 1.0 / den;
    for d in dlogits.iter_mut() {
        *d = ((*d as f64) * inv) as f32;
    }
    dlogits[label] -= 1.0;
    den.ln() - (logits[label] as f64 - mx)
}

/// Loss-only variant of [`softmax_xent`] (no gradient buffer needed).
pub fn softmax_xent_loss(logits: &[f32], label: usize) -> f64 {
    assert!(label < logits.len(), "label {label} outside {} classes", logits.len());
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let den: f64 = logits.iter().map(|&l| ((l as f64) - mx).exp()).sum();
    den.ln() - (logits[label] as f64 - mx)
}

// ---------------------------------------------------------------------------
// Attention backward: dense
// ---------------------------------------------------------------------------

/// Query rows per block (matches the dense forward's blocking).
const QB: usize = 32;

/// Backward of single-head dense attention `out = softmax(QKᵀ/√d)·V` for
/// row-major `[n, d]` inputs. Writes `dq` and accumulates nothing outside
/// its outputs: `dq` is overwritten per query block, `dk`/`dv` are zeroed
/// here and then accumulated across query blocks. The softmax
/// probabilities are recomputed blockwise (same expression order as the
/// forward), so no `[n, n]` tape is ever materialized.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    dout: &[f32],
    ws: &mut Workspace,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    assert_eq!(q.len(), n * d, "q must be [n, d]");
    assert_eq!(k.len(), n * d, "k must be [n, d]");
    assert_eq!(v.len(), n * d, "v must be [n, d]");
    assert_eq!(dout.len(), n * d, "dout must be [n, d]");
    assert_eq!(dq.len(), n * d, "dq must be [n, d]");
    assert_eq!(dk.len(), n * d, "dk must be [n, d]");
    assert_eq!(dv.len(), n * d, "dv must be [n, d]");
    dk.fill(0.0);
    dv.fill(0.0);
    if n == 0 || d == 0 {
        return;
    }
    let scale = 1.0 / (d as f32).sqrt();
    let rows_max = QB.min(n);
    let mut p = ws.take_f32("dense.bwd.p", rows_max * n);
    let mut ds = ws.take_f32("dense.bwd.ds", rows_max * n);
    for r0 in (0..n).step_by(QB) {
        let rows = QB.min(n - r0);
        let qblk = &q[r0 * d..(r0 + rows) * d];
        let doblk = &dout[r0 * d..(r0 + rows) * d];
        // Recompute P = softmax(Q_blk Kᵀ · scale) exactly like the
        // forward (scale folded into the softmax's exp pass there too).
        let pblk = &mut p[..rows * n];
        crate::kernels::linalg::matmul_nt(qblk, k, rows, n, d, pblk);
        crate::kernels::linalg::softmax_rows_scaled(pblk, rows, n, scale);
        // dP[i, j] = dot(dout_i, v_j).
        let dsblk = &mut ds[..rows * n];
        crate::kernels::linalg::matmul_nt(doblk, v, rows, n, d, dsblk);
        // dV[j] += Σ_i P[i, j] · dout_i (uses P before it turns into dS).
        matmul_tn_acc(pblk, doblk, rows, n, d, dv);
        // dS[i, j] = scale · P[i, j] · (dP[i, j] − Σ_t P[i, t]·dP[i, t]).
        for (prow, dsrow) in pblk.chunks_exact(n).zip(dsblk.chunks_exact_mut(n)) {
            let rowsum: f32 = prow.iter().zip(dsrow.iter()).map(|(&pv, &dp)| pv * dp).sum();
            for (&pv, dsv) in prow.iter().zip(dsrow.iter_mut()) {
                *dsv = pv * (*dsv - rowsum) * scale;
            }
        }
        // dQ_blk = dS · K ; dK += dSᵀ · Q_blk (scale already folded in).
        matmul_nn(dsblk, k, rows, n, d, &mut dq[r0 * d..(r0 + rows) * d]);
        matmul_tn_acc(dsblk, qblk, rows, n, d, dk);
    }
    ws.give_f32("dense.bwd.p", p);
    ws.give_f32("dense.bwd.ds", ds);
}

// ---------------------------------------------------------------------------
// Attention backward: MiTA (straight-through selection)
// ---------------------------------------------------------------------------

/// Backward of the single-head MiTA forward
/// ([`crate::kernels::mita::mita_attention`]) under the straight-through
/// selection convention. Landmarks, top-k KV picks, and argmax routing
/// are recomputed with the forward's exact functions — bit-identical
/// indices — and held constant; gradients then flow through each query's
/// softmax over its expert's gathered KV pairs, exactly as in dense
/// attention restricted to the picked rows. Packed and overflow queries
/// share one code path here (the forward's capacity packing only
/// reorders execution, never the math). `dq` is overwritten; `dk` / `dv`
/// are zeroed then scatter-accumulated in query order.
#[allow(clippy::too_many_arguments)]
pub fn mita_attention_backward(
    q: &[f32],
    kmat: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    cfg: &MitaKernelConfig,
    dout: &[f32],
    ws: &mut Workspace,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    assert_eq!(q.len(), n * d, "q must be [n, d]");
    assert_eq!(kmat.len(), n * d, "k must be [n, d]");
    assert_eq!(v.len(), n * d, "v must be [n, d]");
    assert_eq!(dout.len(), n * d, "dout must be [n, d]");
    assert_eq!(dq.len(), n * d, "dq must be [n, d]");
    assert_eq!(dk.len(), n * d, "dk must be [n, d]");
    assert_eq!(dv.len(), n * d, "dv must be [n, d]");
    dk.fill(0.0);
    dv.fill(0.0);
    if n == 0 || d == 0 {
        return;
    }
    let cfg = cfg.clamped(n);
    let (m, kk) = (cfg.m, cfg.k);
    let scale = 1.0 / (d as f32).sqrt();

    // Recompute the forward's selection structure with the *same
    // function* the forward kernel runs (`select_experts`) — same
    // inputs, same code ⇒ the same indices, by construction.
    let mut landmarks = ws.take_f32("mita.bwd.landmarks", m * d);
    let mut s = ws.take_f32("mita.bwd.scores", n * m);
    let mut col = ws.take_f32("mita.bwd.topk_col", n);
    let mut order = ws.take_usize("mita.bwd.order", n);
    let mut topk = ws.take_usize("mita.bwd.topk", m * kk);
    let mut route_logits = ws.take_f32("mita.bwd.route", n * m);
    let mut assign = ws.take_usize("mita.bwd.assign", n);
    crate::kernels::mita::select_experts(
        q,
        kmat,
        n,
        d,
        &cfg,
        &mut landmarks,
        &mut s,
        &mut col,
        &mut order,
        &mut topk,
        &mut route_logits,
        &mut assign,
    );

    // Per-query softmax-attention backward over the expert's picks.
    let mut w = ws.take_f32("mita.bwd.w", kk);
    let mut dp = ws.take_f32("mita.bwd.dp", kk);
    for qi in 0..n {
        let e = assign[qi];
        let picks = &topk[e * kk..(e + 1) * kk];
        let qrow = &q[qi * d..(qi + 1) * d];
        let dorow = &dout[qi * d..(qi + 1) * d];
        // Recompute the forward's weights (same order as attend_one).
        for (l, &ki) in w.iter_mut().zip(picks) {
            *l = dot(qrow, &kmat[ki * d..(ki + 1) * d]) * scale;
        }
        crate::kernels::linalg::softmax_in_place(&mut w);
        // dp_j = dot(dout_i, v_pj); rowsum = Σ_j w_j dp_j.
        let mut rowsum = 0.0f32;
        for ((dpj, &wj), &ki) in dp.iter_mut().zip(w.iter()).zip(picks) {
            *dpj = dot(dorow, &v[ki * d..(ki + 1) * d]);
            rowsum += wj * *dpj;
        }
        // dlogit_j = w_j (dp_j − rowsum); scatter into dq/dk/dv.
        let dqrow = &mut dq[qi * d..(qi + 1) * d];
        dqrow.fill(0.0);
        for ((&dpj, &wj), &ki) in dp.iter().zip(w.iter()).zip(picks) {
            let dlogit = wj * (dpj - rowsum) * scale;
            axpy(dlogit, &kmat[ki * d..(ki + 1) * d], dqrow);
            axpy(dlogit, qrow, &mut dk[ki * d..(ki + 1) * d]);
            axpy(wj, dorow, &mut dv[ki * d..(ki + 1) * d]);
        }
    }

    ws.give_f32("mita.bwd.landmarks", landmarks);
    ws.give_f32("mita.bwd.scores", s);
    ws.give_f32("mita.bwd.topk_col", col);
    ws.give_f32("mita.bwd.route", route_logits);
    ws.give_f32("mita.bwd.w", w);
    ws.give_f32("mita.bwd.dp", dp);
    ws.give_usize("mita.bwd.order", order);
    ws.give_usize("mita.bwd.topk", topk);
    ws.give_usize("mita.bwd.assign", assign);
}

// ---------------------------------------------------------------------------
// Multi-head dispatch
// ---------------------------------------------------------------------------

/// Which attention backward a block uses — resolved once per model from
/// the block's registry name (the backward is kernel-specific math, not a
/// registry lookup).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    /// Routed MiTA attention (straight-through selection backward).
    Mita,
    /// Dense softmax attention (exact O(n²) backward).
    Dense,
}

impl AttnKind {
    /// Map a kernel registry name to its backward implementation.
    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            OP_ATTN_MITA => Ok(AttnKind::Mita),
            OP_ATTN_DENSE => Ok(AttnKind::Dense),
            other => anyhow::bail!(
                "no native backward for attention kernel {other:?} \
                 (trainable kernels: {OP_ATTN_MITA}, {OP_ATTN_DENSE})"
            ),
        }
    }
}

/// Multi-head attention backward over model-dim layout `[n, dim]`
/// (`dim = heads · dh`), mirroring the forward's per-head gather/scatter:
/// each head is gathered to contiguous `[n, dh]`, solved with the
/// kernel-specific single-head backward, and scattered into the `[n,
/// dim]` gradients. `dq`/`dk`/`dv` are fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn attention_backward_mh(
    kind: AttnKind,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    heads: usize,
    dim: usize,
    cfg: &MitaKernelConfig,
    dout: &[f32],
    ws: &mut Workspace,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    assert!(heads >= 1 && dim % heads == 0, "dim {dim} must divide into {heads} heads");
    assert_eq!(q.len(), n * dim);
    assert_eq!(dout.len(), n * dim);
    assert_eq!(dq.len(), n * dim);
    assert_eq!(dk.len(), n * dim);
    assert_eq!(dv.len(), n * dim);
    if n == 0 || dim == 0 {
        return;
    }
    let dh = dim / heads;
    let mut qh = ws.take_f32("bwd.mh.q", n * dh);
    let mut kh = ws.take_f32("bwd.mh.k", n * dh);
    let mut vh = ws.take_f32("bwd.mh.v", n * dh);
    let mut doh = ws.take_f32("bwd.mh.dout", n * dh);
    let mut dqh = ws.take_f32("bwd.mh.dq", n * dh);
    let mut dkh = ws.take_f32("bwd.mh.dk", n * dh);
    let mut dvh = ws.take_f32("bwd.mh.dv", n * dh);
    for h in 0..heads {
        gather_head(q, n, dim, dh, h, &mut qh);
        gather_head(k, n, dim, dh, h, &mut kh);
        gather_head(v, n, dim, dh, h, &mut vh);
        gather_head(dout, n, dim, dh, h, &mut doh);
        match kind {
            AttnKind::Mita => mita_attention_backward(
                &qh, &kh, &vh, n, dh, cfg, &doh, ws, &mut dqh, &mut dkh, &mut dvh,
            ),
            AttnKind::Dense => dense_attention_backward(
                &qh, &kh, &vh, n, dh, &doh, ws, &mut dqh, &mut dkh, &mut dvh,
            ),
        }
        scatter_head(&dqh, n, dim, dh, h, dq);
        scatter_head(&dkh, n, dim, dh, h, dk);
        scatter_head(&dvh, n, dim, dh, h, dv);
    }
    ws.give_f32("bwd.mh.q", qh);
    ws.give_f32("bwd.mh.k", kh);
    ws.give_f32("bwd.mh.v", vh);
    ws.give_f32("bwd.mh.dout", doh);
    ws.give_f32("bwd.mh.dq", dqh);
    ws.give_f32("bwd.mh.dk", dkh);
    ws.give_f32("bwd.mh.dv", dvh);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn matmul_adjoint_shapes_agree_with_naive() {
        let (p, q, r) = (3usize, 4usize, 5usize);
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..p * q).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..q * r).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut got = vec![0.0f32; p * r];
        matmul_nn(&a, &b, p, q, r, &mut got);
        for i in 0..p {
            for j in 0..r {
                let want: f32 = (0..q).map(|t| a[i * q + t] * b[t * r + j]).sum();
                assert!((got[i * r + j] - want).abs() < 1e-5);
            }
        }
        // Accumulating variant adds on top.
        let snapshot = got.clone();
        matmul_nn_acc(&a, &b, p, q, r, &mut got);
        for (g, s) in got.iter().zip(&snapshot) {
            assert!((g - 2.0 * s).abs() < 1e-5);
        }

        // Aᵀ·B against a naive loop.
        let n = p;
        let mut tn = vec![0.0f32; q * r];
        let b2: Vec<f32> = (0..n * r).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        matmul_tn_acc(&a, &b2, n, q, r, &mut tn);
        for j in 0..q {
            for c in 0..r {
                let want: f32 = (0..n).map(|i| a[i * q + j] * b2[i * r + c]).sum();
                assert!((tn[j * r + c] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn bias_grad_sums_rows() {
        let dy = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut db = vec![0.5f32; 2];
        bias_grad_acc(&dy, &mut db);
        assert_eq!(db, vec![0.5 + 1.0 + 3.0 + 5.0, 0.5 + 2.0 + 4.0 + 6.0]);
    }

    #[test]
    fn softmax_xent_gradient_sums_to_zero() {
        let logits = vec![0.3f32, -1.2, 2.0, 0.0];
        let mut d = vec![0.0f32; 4];
        let loss = softmax_xent(&logits, 2, &mut d);
        assert!(loss > 0.0);
        assert!((loss - softmax_xent_loss(&logits, 2)).abs() < 1e-12);
        let sum: f32 = d.iter().sum();
        assert!(sum.abs() < 1e-6, "softmax-CE gradient rows sum to 0, got {sum}");
        assert!(d[2] < 0.0, "true-class gradient must be negative");
        // Loss equals -log p_label.
        let mx = 2.0f64;
        let den: f64 = logits.iter().map(|&l| ((l as f64) - mx).exp()).sum();
        assert!((loss - (den.ln())).abs() < 1e-9);
    }

    #[test]
    fn attn_kind_resolution() {
        assert_eq!(AttnKind::from_name(OP_ATTN_MITA).unwrap(), AttnKind::Mita);
        assert_eq!(AttnKind::from_name(OP_ATTN_DENSE).unwrap(), AttnKind::Dense);
        assert!(AttnKind::from_name("attn.unknown").is_err());
    }
}
