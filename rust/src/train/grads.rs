//! Flat gradient storage matching [`ModelParams`]' checkpoint order.
//!
//! Gradients live in **one contiguous `Vec<f32>`** whose layout is exactly
//! the tensor order of [`ModelParams::to_tensors`]: `tok_emb`, `pos_emb`,
//! per block (`ln1 g/b`, `wq/bq`, `wk/bk`, `wv/bv`, `wo/bo`, `ln2 g/b`,
//! `w1/b1`, `w2/b2`), `lnf g/b`, `head w/b`. One flat buffer keeps the
//! optimizer a single offset walk, makes per-example gradient staging a
//! plain `[batch · P]` slab, and lets the data-parallel reduction sum
//! examples in a fixed order regardless of thread count.
//!
//! [`view_mut`] splits a flat buffer into named per-tensor slices (the
//! backward pass writes through these); [`param_tensors`] /
//! [`param_tensors_mut`] expose [`ModelParams`] in the *same* order, so
//! "walk params and grads in lockstep" is a zip, never an index
//! recomputation. A test pins that the two walks agree tensor-for-tensor.

use crate::model::params::{BLOCK_TENSORS, EXTRA_TENSORS};
use crate::model::{ModelConfig, ModelParams};

/// Flat gradient buffer for one model (`len == cfg.param_count()`).
#[derive(Debug, Clone)]
pub struct Gradients {
    flat: Vec<f32>,
}

impl Gradients {
    /// A zeroed gradient buffer shaped for `cfg`.
    pub fn zeros(cfg: &ModelConfig) -> Self {
        Gradients { flat: vec![0.0; cfg.param_count()] }
    }

    /// Total f32 gradient entries (equals the model's parameter count).
    pub fn len(&self) -> usize {
        self.flat.len()
    }

    /// True when the buffer holds no entries.
    pub fn is_empty(&self) -> bool {
        self.flat.is_empty()
    }

    /// The flat buffer, read-only.
    pub fn as_slice(&self) -> &[f32] {
        &self.flat
    }

    /// The flat buffer, writable.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.flat
    }

    /// Reset every entry to zero (capacity untouched).
    pub fn fill_zero(&mut self) {
        self.flat.fill(0.0);
    }

    /// Named per-tensor views over the flat buffer.
    pub fn view_mut(&mut self, cfg: &ModelConfig) -> GradsView<'_> {
        view_mut(cfg, &mut self.flat)
    }

    /// Global L2 norm, accumulated in f64 in flat order (deterministic).
    pub fn global_norm(&self) -> f64 {
        self.flat.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt()
    }

    /// Multiply every entry by `s` (gradient clipping / batch averaging).
    pub fn scale(&mut self, s: f32) {
        for g in self.flat.iter_mut() {
            *g *= s;
        }
    }
}

/// Gradient slices of one transformer block, in checkpoint order.
#[derive(Debug)]
pub struct BlockGrads<'a> {
    pub ln1_g: &'a mut [f32],
    pub ln1_b: &'a mut [f32],
    pub wq: &'a mut [f32],
    pub bq: &'a mut [f32],
    pub wk: &'a mut [f32],
    pub bk: &'a mut [f32],
    pub wv: &'a mut [f32],
    pub bv: &'a mut [f32],
    pub wo: &'a mut [f32],
    pub bo: &'a mut [f32],
    pub ln2_g: &'a mut [f32],
    pub ln2_b: &'a mut [f32],
    pub w1: &'a mut [f32],
    pub b1: &'a mut [f32],
    pub w2: &'a mut [f32],
    pub b2: &'a mut [f32],
}

/// Named gradient slices over one flat buffer, mirroring [`ModelParams`].
#[derive(Debug)]
pub struct GradsView<'a> {
    pub tok_emb: &'a mut [f32],
    pub pos_emb: &'a mut [f32],
    pub blocks: Vec<BlockGrads<'a>>,
    pub lnf_g: &'a mut [f32],
    pub lnf_b: &'a mut [f32],
    pub head_w: &'a mut [f32],
    pub head_b: &'a mut [f32],
}

/// Split `rest` at `len`, returning the head and leaving the tail.
pub(crate) fn carve<'a>(rest: &mut &'a mut [f32], len: usize) -> &'a mut [f32] {
    let r = std::mem::take(rest);
    let (head, tail) = r.split_at_mut(len);
    *rest = tail;
    head
}

/// Split a flat `[param_count]` buffer into named per-tensor slices in
/// the canonical checkpoint order.
pub fn view_mut<'a>(cfg: &ModelConfig, flat: &'a mut [f32]) -> GradsView<'a> {
    assert_eq!(
        flat.len(),
        cfg.param_count(),
        "flat gradient buffer does not match the model's parameter count"
    );
    let (d, h) = (cfg.dim, cfg.mlp_hidden);
    let mut rest = flat;
    let tok_emb = carve(&mut rest, cfg.vocab * d);
    let pos_emb = carve(&mut rest, cfg.seq_len * d);
    let blocks = (0..cfg.depth)
        .map(|_| BlockGrads {
            ln1_g: carve(&mut rest, d),
            ln1_b: carve(&mut rest, d),
            wq: carve(&mut rest, d * d),
            bq: carve(&mut rest, d),
            wk: carve(&mut rest, d * d),
            bk: carve(&mut rest, d),
            wv: carve(&mut rest, d * d),
            bv: carve(&mut rest, d),
            wo: carve(&mut rest, d * d),
            bo: carve(&mut rest, d),
            ln2_g: carve(&mut rest, d),
            ln2_b: carve(&mut rest, d),
            w1: carve(&mut rest, h * d),
            b1: carve(&mut rest, h),
            w2: carve(&mut rest, d * h),
            b2: carve(&mut rest, d),
        })
        .collect();
    let lnf_g = carve(&mut rest, d);
    let lnf_b = carve(&mut rest, d);
    let head_w = carve(&mut rest, cfg.classes * d);
    let head_b = carve(&mut rest, cfg.classes);
    debug_assert!(rest.is_empty());
    GradsView { tok_emb, pos_emb, blocks, lnf_g, lnf_b, head_w, head_b }
}

/// Every parameter tensor of a model as read-only slices, in the same
/// order the flat gradient buffer uses.
pub fn param_tensors(p: &ModelParams) -> Vec<&[f32]> {
    let mut out: Vec<&[f32]> =
        Vec::with_capacity(EXTRA_TENSORS + BLOCK_TENSORS * p.blocks.len());
    out.push(&p.tok_emb);
    out.push(&p.pos_emb);
    for b in &p.blocks {
        out.push(&b.ln1_g);
        out.push(&b.ln1_b);
        out.push(&b.wq);
        out.push(&b.bq);
        out.push(&b.wk);
        out.push(&b.bk);
        out.push(&b.wv);
        out.push(&b.bv);
        out.push(&b.wo);
        out.push(&b.bo);
        out.push(&b.ln2_g);
        out.push(&b.ln2_b);
        out.push(&b.w1);
        out.push(&b.b1);
        out.push(&b.w2);
        out.push(&b.b2);
    }
    out.push(&p.lnf_g);
    out.push(&p.lnf_b);
    out.push(&p.head_w);
    out.push(&p.head_b);
    out
}

/// Like [`param_tensors`], but mutable — the optimizer walks these in
/// lockstep with the flat gradient / moment buffers.
pub fn param_tensors_mut(p: &mut ModelParams) -> Vec<&mut [f32]> {
    let mut out: Vec<&mut [f32]> =
        Vec::with_capacity(EXTRA_TENSORS + BLOCK_TENSORS * p.blocks.len());
    out.push(p.tok_emb.as_mut_slice());
    out.push(p.pos_emb.as_mut_slice());
    for b in &mut p.blocks {
        out.push(b.ln1_g.as_mut_slice());
        out.push(b.ln1_b.as_mut_slice());
        out.push(b.wq.as_mut_slice());
        out.push(b.bq.as_mut_slice());
        out.push(b.wk.as_mut_slice());
        out.push(b.bk.as_mut_slice());
        out.push(b.wv.as_mut_slice());
        out.push(b.bv.as_mut_slice());
        out.push(b.wo.as_mut_slice());
        out.push(b.bo.as_mut_slice());
        out.push(b.ln2_g.as_mut_slice());
        out.push(b.ln2_b.as_mut_slice());
        out.push(b.w1.as_mut_slice());
        out.push(b.b1.as_mut_slice());
        out.push(b.w2.as_mut_slice());
        out.push(b.b2.as_mut_slice());
    }
    out.push(p.lnf_g.as_mut_slice());
    out.push(p.lnf_b.as_mut_slice());
    out.push(p.head_w.as_mut_slice());
    out.push(p.head_b.as_mut_slice());
    out
}

/// Copy every parameter into one flat vector (canonical order).
pub fn flatten_params(p: &ModelParams) -> Vec<f32> {
    let mut out = Vec::with_capacity(p.count());
    for t in param_tensors(p) {
        out.extend_from_slice(t);
    }
    out
}

/// Overwrite every parameter from one flat vector (inverse of
/// [`flatten_params`]).
pub fn load_flat(p: &mut ModelParams, flat: &[f32]) {
    let mut off = 0usize;
    for t in param_tensors_mut(p) {
        t.copy_from_slice(&flat[off..off + t.len()]);
        off += t.len();
    }
    assert_eq!(off, flat.len(), "flat parameter vector does not match the model");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::OP_ATTN_MITA;

    fn cfg() -> ModelConfig {
        ModelConfig::new(9, 12, 8, 2, 2, 16, 3, OP_ATTN_MITA)
    }

    #[test]
    fn view_partitions_the_whole_buffer() {
        let c = cfg();
        let mut g = Gradients::zeros(&c);
        assert_eq!(g.len(), c.param_count());
        let v = g.view_mut(&c);
        let mut total = v.tok_emb.len() + v.pos_emb.len();
        assert_eq!(v.tok_emb.len(), c.vocab * c.dim);
        assert_eq!(v.blocks.len(), c.depth);
        for b in &v.blocks {
            assert_eq!(b.wq.len(), c.dim * c.dim);
            assert_eq!(b.w1.len(), c.mlp_hidden * c.dim);
            assert_eq!(b.w2.len(), c.dim * c.mlp_hidden);
            total += b.ln1_g.len()
                + b.ln1_b.len()
                + b.wq.len()
                + b.bq.len()
                + b.wk.len()
                + b.bk.len()
                + b.wv.len()
                + b.bv.len()
                + b.wo.len()
                + b.bo.len()
                + b.ln2_g.len()
                + b.ln2_b.len()
                + b.w1.len()
                + b.b1.len()
                + b.w2.len()
                + b.b2.len();
        }
        total += v.lnf_g.len() + v.lnf_b.len() + v.head_w.len() + v.head_b.len();
        assert_eq!(total, c.param_count());
    }

    #[test]
    fn grad_view_and_param_walk_share_one_order() {
        // The optimizer's core assumption: the flat gradient layout and
        // the parameter tensor walk have the same tensor boundaries.
        let c = cfg();
        let mut p = ModelParams::init(&c, 5);
        let mut g = Gradients::zeros(&c);
        // Stamp each grad tensor with its walk index...
        {
            let v = g.view_mut(&c);
            let mut tensors: Vec<&mut [f32]> = vec![v.tok_emb, v.pos_emb];
            for b in v.blocks {
                tensors.extend([
                    b.ln1_g, b.ln1_b, b.wq, b.bq, b.wk, b.bk, b.wv, b.bv, b.wo, b.bo, b.ln2_g,
                    b.ln2_b, b.w1, b.b1, b.w2, b.b2,
                ]);
            }
            tensors.extend([v.lnf_g, v.lnf_b, v.head_w, v.head_b]);
            for (i, t) in tensors.iter_mut().enumerate() {
                t.fill(i as f32);
            }
        }
        // ...then confirm the parameter walk sees the same boundaries.
        let mut off = 0usize;
        for (i, t) in param_tensors_mut(&mut p).iter().enumerate() {
            let seg = &g.as_slice()[off..off + t.len()];
            assert!(seg.iter().all(|&x| x == i as f32), "tensor {i} misaligned");
            off += t.len();
        }
        assert_eq!(off, g.len());
    }

    #[test]
    fn flatten_roundtrip_and_scale_norm() {
        let c = cfg();
        let mut p = ModelParams::init(&c, 11);
        let flat = flatten_params(&p);
        assert_eq!(flat.len(), c.param_count());
        let mut q = ModelParams::init(&c, 12);
        load_flat(&mut q, &flat);
        assert_eq!(p, q);

        let mut g = Gradients::zeros(&c);
        g.as_mut_slice()[0] = 3.0;
        g.as_mut_slice()[1] = 4.0;
        assert!((g.global_norm() - 5.0).abs() < 1e-12);
        g.scale(0.5);
        assert_eq!(g.as_slice()[0], 1.5);
        assert!(!g.is_empty());

        // load_flat writes through to the model (not a copy).
        let mut flat2 = flat;
        flat2[0] += 1.0;
        load_flat(&mut p, &flat2);
        assert_eq!(p.tok_emb[0], flat2[0]);
    }
}
