//! AdamW with bias correction and global-norm gradient clipping.
//!
//! The optimizer state is two flat f32 moment buffers (`mu`, `nu`)
//! sharing the canonical parameter layout of
//! [`crate::train::grads`]; a step walks the model's tensors in that
//! order (via [`param_tensors_mut`]) zipped against the flat gradient
//! and moment slices — one serial offset walk, deterministic by
//! construction. Per-element math runs in f64 and rounds once back to
//! f32, matching the reference AdamW update:
//!
//! ```text
//! μ ← β₁μ + (1−β₁)g          ν ← β₂ν + (1−β₂)g²
//! μ̂ = μ/(1−β₁ᵗ)              ν̂ = ν/(1−β₂ᵗ)
//! θ ← θ − lr·μ̂/(√ν̂ + ε) − lr·λ·θ        (decoupled weight decay)
//! ```

use crate::model::ModelParams;
use crate::train::grads::{param_tensors_mut, Gradients};

/// AdamW hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamWConfig {
    /// Learning rate.
    pub lr: f64,
    /// First-moment decay β₁.
    pub beta1: f64,
    /// Second-moment decay β₂.
    pub beta2: f64,
    /// Denominator stabilizer ε.
    pub eps: f64,
    /// Decoupled weight decay λ (0 disables).
    pub weight_decay: f64,
    /// Global L2-norm gradient clip (0 disables).
    pub grad_clip: f64,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig {
            lr: 1e-2,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 1.0,
        }
    }
}

impl AdamWConfig {
    /// Same config with a different learning rate.
    pub fn with_lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }
}

/// AdamW optimizer state for one model.
#[derive(Debug, Clone)]
pub struct AdamW {
    /// Hyperparameters (mutable so schedules can adjust `lr` between
    /// steps without rebuilding the moment state).
    pub cfg: AdamWConfig,
    mu: Vec<f32>,
    nu: Vec<f32>,
    steps: usize,
}

impl AdamW {
    /// Fresh (zero-moment) state for `param_count` parameters.
    pub fn new(param_count: usize, cfg: AdamWConfig) -> Self {
        AdamW { cfg, mu: vec![0.0; param_count], nu: vec![0.0; param_count], steps: 0 }
    }

    /// Optimizer steps taken so far (the bias-correction exponent).
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Parameter count this state was sized for.
    pub fn param_count(&self) -> usize {
        self.mu.len()
    }

    /// Apply one update in place. `grads` is consumed as ∂loss/∂θ (it is
    /// rescaled here when clipping triggers). Returns the pre-clip global
    /// gradient norm, for logging.
    pub fn step(&mut self, params: &mut ModelParams, grads: &mut Gradients) -> f64 {
        assert_eq!(
            grads.len(),
            self.mu.len(),
            "gradient buffer does not match the optimizer state"
        );
        let norm = grads.global_norm();
        if self.cfg.grad_clip > 0.0 && norm > self.cfg.grad_clip {
            grads.scale((self.cfg.grad_clip / norm) as f32);
        }
        self.steps += 1;
        let t = self.steps as i32;
        let bc1 = 1.0 - self.cfg.beta1.powi(t);
        let bc2 = 1.0 - self.cfg.beta2.powi(t);
        let (lr, b1, b2, eps, wd) =
            (self.cfg.lr, self.cfg.beta1, self.cfg.beta2, self.cfg.eps, self.cfg.weight_decay);
        let g = grads.as_slice();
        let mut off = 0usize;
        for tensor in param_tensors_mut(params) {
            for (i, p) in tensor.iter_mut().enumerate() {
                let j = off + i;
                let gd = g[j] as f64;
                let m64 = b1 * (self.mu[j] as f64) + (1.0 - b1) * gd;
                let v64 = b2 * (self.nu[j] as f64) + (1.0 - b2) * gd * gd;
                self.mu[j] = m64 as f32;
                self.nu[j] = v64 as f32;
                let mhat = m64 / bc1;
                let vhat = v64 / bc2;
                let upd = lr * (mhat / (vhat.sqrt() + eps)) + lr * wd * (*p as f64);
                *p = ((*p as f64) - upd) as f32;
            }
            off += tensor.len();
        }
        assert_eq!(off, g.len(), "parameter walk does not cover the gradient buffer");
        norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::OP_ATTN_DENSE;
    use crate::model::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig::new(5, 6, 4, 2, 1, 8, 3, OP_ATTN_DENSE)
    }

    #[test]
    fn first_step_matches_bias_corrected_closed_form() {
        // At t = 1, μ̂ = g and ν̂ = g² exactly, so the update (with λ = 0,
        // no clip) is lr · g / (|g| + ε) ≈ lr · sign(g).
        let c = cfg();
        let mut p = ModelParams::init(&c, 1);
        let before = p.tok_emb.clone();
        let opt_cfg = AdamWConfig {
            lr: 0.1,
            weight_decay: 0.0,
            grad_clip: 0.0,
            ..AdamWConfig::default()
        };
        let mut opt = AdamW::new(c.param_count(), opt_cfg);
        let mut g = Gradients::zeros(&c);
        g.as_mut_slice()[0] = 0.5; // first tok_emb coordinate
        g.as_mut_slice()[1] = -2.0;
        let norm = opt.step(&mut p, &mut g);
        assert!((norm - (0.25f64 + 4.0).sqrt()).abs() < 1e-6);
        assert_eq!(opt.steps(), 1);
        assert!((p.tok_emb[0] - (before[0] - 0.1)).abs() < 1e-5, "≈ −lr·sign(g)");
        assert!((p.tok_emb[1] - (before[1] + 0.1)).abs() < 1e-5);
        // Untouched coordinates (zero grad, zero decay) stay put.
        assert_eq!(p.tok_emb[2], before[2]);
    }

    #[test]
    fn clipping_rescales_to_the_norm_budget() {
        let c = cfg();
        let mut p = ModelParams::init(&c, 2);
        let cfg = AdamWConfig { grad_clip: 1.0, ..Default::default() };
        let mut opt = AdamW::new(c.param_count(), cfg);
        let mut g = Gradients::zeros(&c);
        g.as_mut_slice()[0] = 3.0;
        g.as_mut_slice()[1] = 4.0; // norm 5 > clip 1
        let norm = opt.step(&mut p, &mut g);
        assert!((norm - 5.0).abs() < 1e-6, "returns the pre-clip norm");
        assert!((g.global_norm() - 1.0).abs() < 1e-5, "grads rescaled to the budget");
    }

    #[test]
    fn weight_decay_shrinks_unused_params() {
        let c = cfg();
        let mut p = ModelParams::init(&c, 3);
        let before = p.head_w.clone();
        let mut opt = AdamW::new(
            c.param_count(),
            AdamWConfig { lr: 0.1, weight_decay: 0.1, ..Default::default() },
        );
        let mut g = Gradients::zeros(&c); // zero gradient everywhere
        opt.step(&mut p, &mut g);
        for (after, &b) in p.head_w.iter().zip(&before) {
            assert!((after - b * (1.0 - 0.1 * 0.1)).abs() < 1e-6, "θ(1 − lr·λ)");
        }
    }

    #[test]
    fn steps_are_deterministic() {
        let c = cfg();
        let run = || {
            let mut p = ModelParams::init(&c, 4);
            let mut opt = AdamW::new(c.param_count(), AdamWConfig::default());
            for s in 0..5 {
                let mut g = Gradients::zeros(&c);
                for (i, gv) in g.as_mut_slice().iter_mut().enumerate() {
                    *gv = ((i * 7 + s * 13) % 11) as f32 * 0.01 - 0.05;
                }
                opt.step(&mut p, &mut g);
            }
            p
        };
        assert_eq!(run(), run());
    }
}
