//! Whole-model loss + exact gradients for [`MitaModel`].
//!
//! Each example runs a **tape forward** — the same math as
//! [`MitaModel::forward`] (it reuses the transformer's own
//! `layer_norm_rows` / `add_bias_rows` / `gelu_in_place` helpers and the
//! serial attention kernels), but keeping every intermediate activation
//! in workspace-owned tape buffers — followed by the reverse sweep built
//! from [`crate::train::backward`]'s layer adjoints. Both run serially
//! inside one (example) work item over a pooled [`Workspace`], so the
//! whole step is allocation-free in steady state.
//!
//! Batch parallelism and determinism: [`loss_and_gradients`] fans
//! examples out over [`par_chunks_mut`] — each example accumulates into
//! its **own** gradient slab — and then reduces slabs in *example-index
//! order* per parameter chunk. The summation order is therefore a pure
//! function of the batch, never of the thread schedule: loss curves and
//! gradients are bit-identical for any `MITA_NUM_THREADS`.

use anyhow::Result;

use crate::kernels::linalg::{dot, matmul_nt, scale_in_place};
use crate::kernels::par::par_chunks_mut;
use crate::kernels::workspace::{Workspace, WorkspacePool};
use crate::kernels::{dense_attention_mh, mita_attention_mh, MitaStats};
use crate::model::transformer::{add_bias_rows, gelu_in_place, layer_norm_rows};
use crate::model::MitaModel;
use crate::train::backward::{
    attention_backward_mh, bias_grad_acc, gelu_backward, layer_norm_backward, matmul_nn,
    matmul_nn_acc, matmul_tn_acc, softmax_xent, AttnKind,
};
use crate::train::grads::{view_mut, Gradients};

/// Parameters summed per reduction chunk (the unit of parallelism in the
/// deterministic gradient reduction).
const REDUCE_CHUNK: usize = 4096;

/// Reusable per-example staging for one training step: gradient slab +
/// loss/accuracy record per example. Kept across steps so steady-state
/// training never touches the allocator.
#[derive(Debug, Default)]
pub struct TrainScratch {
    slots: Vec<ExampleSlot>,
}

#[derive(Debug, Default)]
struct ExampleSlot {
    grad: Vec<f32>,
    loss: f64,
    correct: bool,
}

/// Result of one batch's loss/gradient computation.
#[derive(Debug, Clone, Copy)]
pub struct BatchOutcome {
    /// Mean per-example cross-entropy loss.
    pub loss: f64,
    /// Examples whose argmax logit hit the label.
    pub correct: usize,
    /// Examples in the batch.
    pub examples: usize,
}

impl BatchOutcome {
    /// Batch accuracy in [0, 1].
    pub fn accuracy(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct as f64 / self.examples as f64
        }
    }
}

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean loss and mean gradients of `model` on one labelled token batch.
///
/// `tokens` is row-major `[batch, seq_len]`, `labels` is `[batch]`.
/// `grads` receives `∂(mean loss)/∂θ` in the canonical flat layout;
/// MiTA routing statistics from the training forward accumulate into
/// `stats`. Bit-identical across thread counts (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn loss_and_gradients(
    model: &MitaModel,
    tokens: &[i32],
    labels: &[i32],
    batch: usize,
    pool: &WorkspacePool,
    scratch: &mut TrainScratch,
    grads: &mut Gradients,
    stats: &mut MitaStats,
) -> Result<BatchOutcome> {
    let cfg = &model.cfg;
    let n = cfg.seq_len;
    anyhow::ensure!(batch >= 1, "empty batch");
    anyhow::ensure!(
        tokens.len() == batch * n,
        "tokens hold {} ids, want {} for [b={batch}, n={n}]",
        tokens.len(),
        batch * n
    );
    anyhow::ensure!(labels.len() == batch, "labels hold {} entries, want {batch}", labels.len());
    for (i, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            (0..cfg.vocab as i32).contains(&t),
            "token {t} at flat position {i} outside vocab 0..{}",
            cfg.vocab
        );
    }
    for (i, &y) in labels.iter().enumerate() {
        anyhow::ensure!(
            (0..cfg.classes as i32).contains(&y),
            "label {y} for example {i} outside 0..{}",
            cfg.classes
        );
    }
    // Resolve every block's backward up front (fail before any compute).
    let kinds: Vec<AttnKind> = cfg
        .block_kernels
        .iter()
        .map(|name| AttnKind::from_name(name))
        .collect::<Result<Vec<_>>>()?;
    let pcount = cfg.param_count();
    anyhow::ensure!(grads.len() == pcount, "gradient buffer does not match the model");

    if scratch.slots.len() < batch {
        scratch.slots.resize_with(batch, ExampleSlot::default);
    }
    {
        let slots = &mut scratch.slots[..batch];
        par_chunks_mut(slots, 1, |i, chunk| {
            let slot = &mut chunk[0];
            slot.grad.resize(pcount, 0.0);
            slot.grad.fill(0.0);
            let mut pooled = pool.acquire();
            let (ws, wstats) = pooled.parts();
            let (loss, correct) = example_backward(
                model,
                &kinds,
                &tokens[i * n..(i + 1) * n],
                labels[i] as usize,
                ws,
                wstats,
                &mut slot.grad,
            );
            slot.loss = loss;
            slot.correct = correct;
        });
    }
    pool.collect_stats(stats);

    // Deterministic reduction: for every parameter, sum the per-example
    // contributions in example-index order — the order is fixed by the
    // batch regardless of which thread handles which chunk.
    {
        let slots = &scratch.slots[..batch];
        par_chunks_mut(grads.as_mut_slice(), REDUCE_CHUNK, |ci, gchunk| {
            let off = ci * REDUCE_CHUNK;
            gchunk.fill(0.0);
            for slot in slots {
                for (g, &e) in gchunk.iter_mut().zip(&slot.grad[off..off + gchunk.len()]) {
                    *g += e;
                }
            }
        });
    }
    grads.scale(1.0 / batch as f32);

    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for slot in &scratch.slots[..batch] {
        loss += slot.loss;
        correct += slot.correct as usize;
    }
    Ok(BatchOutcome { loss: loss / batch as f64, correct, examples: batch })
}

/// One example's tape forward + reverse sweep. `grad` must be a zeroed
/// `[param_count]` slab; the example's gradients accumulate into it.
/// Returns (cross-entropy loss, argmax-correct).
fn example_backward(
    model: &MitaModel,
    kinds: &[AttnKind],
    tokens: &[i32],
    label: usize,
    ws: &mut Workspace,
    stats: &mut MitaStats,
    grad: &mut [f32],
) -> (f64, bool) {
    let cfg = &model.cfg;
    let p = &model.params;
    let (n, d, heads, hid) = (cfg.seq_len, cfg.dim, cfg.heads, cfg.mlp_hidden);
    let (classes, depth) = (cfg.classes, cfg.depth);
    let per = n * d;
    let nh = n * hid;
    debug_assert_eq!(tokens.len(), n);
    debug_assert_eq!(kinds.len(), depth);
    debug_assert_eq!(grad.len(), cfg.param_count());

    // ---- tape buffers (workspace-owned, warm in steady state) ----
    let mut h = ws.take_f32("train.h", (depth + 1) * per);
    let mut mid = ws.take_f32("train.mid", depth * per);
    let mut y1 = ws.take_f32("train.y1", depth * per);
    let mut qkv = ws.take_f32("train.qkv", depth * 3 * per);
    let mut attn = ws.take_f32("train.attn", depth * per);
    let mut ln2 = ws.take_f32("train.ln2", depth * per);
    let mut hpre = ws.take_f32("train.hpre", depth * nh);
    let mut hpost = ws.take_f32("train.hpost", depth * nh);
    let mut lnf = ws.take_f32("train.lnf", per);
    let mut mean = ws.take_f32("train.mean", d);
    let mut logits = ws.take_f32("train.logits", classes);
    let mut proj = ws.take_f32("train.proj", per);

    // ---- forward, writing the tape ----
    // Token embedding + learned positions.
    for (t, (&tok, hrow)) in tokens.iter().zip(h[..per].chunks_exact_mut(d)).enumerate() {
        let tok = tok as usize;
        let erow = &p.tok_emb[tok * d..(tok + 1) * d];
        let prow = &p.pos_emb[t * d..(t + 1) * d];
        for ((hv, &e), &pv) in hrow.iter_mut().zip(erow).zip(prow) {
            *hv = e + pv;
        }
    }
    for (l, (block, &kind)) in p.blocks.iter().zip(kinds).enumerate() {
        // Pre-LN + fused Q/K/V projections.
        layer_norm_rows(
            &h[l * per..(l + 1) * per],
            d,
            &block.ln1_g,
            &block.ln1_b,
            &mut y1[l * per..(l + 1) * per],
        );
        {
            let y_l = &y1[l * per..(l + 1) * per];
            let (qb, rest) = qkv[l * 3 * per..(l + 1) * 3 * per].split_at_mut(per);
            let (kb, vb) = rest.split_at_mut(per);
            matmul_nt(y_l, &block.wq, n, d, d, qb);
            add_bias_rows(qb, &block.bq);
            matmul_nt(y_l, &block.wk, n, d, d, kb);
            add_bias_rows(kb, &block.bk);
            matmul_nt(y_l, &block.wv, n, d, d, vb);
            add_bias_rows(vb, &block.bv);
        }
        // Attention (serial multi-head kernels; the parallelism is the
        // surrounding per-example fan-out).
        {
            let qkv_l = &qkv[l * 3 * per..(l + 1) * 3 * per];
            let (qs, ks, vs) = (&qkv_l[..per], &qkv_l[per..2 * per], &qkv_l[2 * per..]);
            let out = &mut attn[l * per..(l + 1) * per];
            match kind {
                AttnKind::Mita => {
                    mita_attention_mh(qs, ks, vs, n, heads, d, &cfg.mita, ws, out, stats)
                }
                AttnKind::Dense => dense_attention_mh(qs, ks, vs, n, heads, d, ws, out),
            }
        }
        // Output projection + residual into `mid`.
        matmul_nt(&attn[l * per..(l + 1) * per], &block.wo, n, d, d, &mut proj);
        add_bias_rows(&mut proj, &block.bo);
        {
            let x = &h[l * per..(l + 1) * per];
            for ((mv, &xv), &pv) in
                mid[l * per..(l + 1) * per].iter_mut().zip(x).zip(proj.iter())
            {
                *mv = xv + pv;
            }
        }
        // Pre-LN GELU MLP + residual into the next h snapshot.
        layer_norm_rows(
            &mid[l * per..(l + 1) * per],
            d,
            &block.ln2_g,
            &block.ln2_b,
            &mut ln2[l * per..(l + 1) * per],
        );
        matmul_nt(
            &ln2[l * per..(l + 1) * per],
            &block.w1,
            n,
            hid,
            d,
            &mut hpre[l * nh..(l + 1) * nh],
        );
        add_bias_rows(&mut hpre[l * nh..(l + 1) * nh], &block.b1);
        hpost[l * nh..(l + 1) * nh].copy_from_slice(&hpre[l * nh..(l + 1) * nh]);
        gelu_in_place(&mut hpost[l * nh..(l + 1) * nh]);
        matmul_nt(&hpost[l * nh..(l + 1) * nh], &block.w2, n, d, hid, &mut proj);
        add_bias_rows(&mut proj, &block.b2);
        {
            let mid_l = &mid[l * per..(l + 1) * per];
            for ((hv, &mv), &pv) in
                h[(l + 1) * per..(l + 2) * per].iter_mut().zip(mid_l).zip(proj.iter())
            {
                *hv = mv + pv;
            }
        }
    }
    // Final LN → mean-pool → classifier head.
    layer_norm_rows(&h[depth * per..], d, &p.lnf_g, &p.lnf_b, &mut lnf);
    mean.fill(0.0);
    for row in lnf.chunks_exact(d) {
        for (mc, &v) in mean.iter_mut().zip(row) {
            *mc += v;
        }
    }
    scale_in_place(&mut mean, 1.0 / n as f32);
    for (lc, (wrow, &bc)) in logits.iter_mut().zip(p.head_w.chunks_exact(d).zip(&p.head_b)) {
        *lc = dot(&mean, wrow) + bc;
    }

    // ---- loss seed ----
    let mut dlogits = ws.take_f32("train.dlogits", classes);
    let loss = softmax_xent(&logits, label, &mut dlogits);
    let correct = argmax(&logits) == label;

    // ---- reverse sweep ----
    let mut gv = view_mut(cfg, grad);
    let mut dmean = ws.take_f32("train.dmean", d);
    matmul_nn(&dlogits, &p.head_w, 1, classes, d, &mut dmean);
    matmul_tn_acc(&dlogits, &mean, 1, classes, d, gv.head_w);
    bias_grad_acc(&dlogits, gv.head_b);
    // Mean-pool adjoint: every sequence row receives dmean / n.
    let mut dlnf = ws.take_f32("train.dlnf", per);
    for drow in dlnf.chunks_exact_mut(d) {
        for (dv, &mv) in drow.iter_mut().zip(dmean.iter()) {
            *dv = mv / n as f32;
        }
    }
    let mut dh = ws.take_f32("train.dh", per);
    layer_norm_backward(&h[depth * per..], d, &p.lnf_g, &dlnf, &mut dh, gv.lnf_g, gv.lnf_b);

    let mut dtmp = ws.take_f32("train.dtmp", per);
    let mut dhid = ws.take_f32("train.dhid", nh);
    let mut dhid2 = ws.take_f32("train.dhid2", nh);
    let mut dln2 = ws.take_f32("train.dln2", per);
    let mut dattn = ws.take_f32("train.dattn", per);
    let mut dq = ws.take_f32("train.dq", per);
    let mut dkb = ws.take_f32("train.dk", per);
    let mut dvb = ws.take_f32("train.dv", per);
    let mut dy = ws.take_f32("train.dy", per);
    for l in (0..depth).rev() {
        let block = &p.blocks[l];
        let bg = &mut gv.blocks[l];
        // dh holds ∂L/∂h_{l+1}. MLP branch first: h_out = mid + mlp, so
        // the mlp-path seed is dh itself.
        matmul_nn(&dh, &block.w2, n, d, hid, &mut dhid);
        matmul_tn_acc(&dh, &hpost[l * nh..(l + 1) * nh], n, d, hid, bg.w2);
        bias_grad_acc(&dh, bg.b2);
        gelu_backward(&hpre[l * nh..(l + 1) * nh], &dhid, &mut dhid2);
        matmul_nn(&dhid2, &block.w1, n, hid, d, &mut dln2);
        matmul_tn_acc(&dhid2, &ln2[l * per..(l + 1) * per], n, hid, d, bg.w1);
        bias_grad_acc(&dhid2, bg.b1);
        layer_norm_backward(
            &mid[l * per..(l + 1) * per],
            d,
            &block.ln2_g,
            &dln2,
            &mut dtmp,
            bg.ln2_g,
            bg.ln2_b,
        );
        // ∂L/∂mid = residual passthrough + LN2 path.
        for (dhv, &tv) in dh.iter_mut().zip(dtmp.iter()) {
            *dhv += tv;
        }
        // Attention branch: mid = x + attn·Woᵀ + bo, proj seed is dh.
        matmul_nn(&dh, &block.wo, n, d, d, &mut dattn);
        matmul_tn_acc(&dh, &attn[l * per..(l + 1) * per], n, d, d, bg.wo);
        bias_grad_acc(&dh, bg.bo);
        {
            let qkv_l = &qkv[l * 3 * per..(l + 1) * 3 * per];
            let (qs, ks, vs) = (&qkv_l[..per], &qkv_l[per..2 * per], &qkv_l[2 * per..]);
            attention_backward_mh(
                kinds[l], qs, ks, vs, n, heads, d, &cfg.mita, &dattn, ws, &mut dq, &mut dkb,
                &mut dvb,
            );
        }
        // Through the Q/K/V projections back to the LN1 output.
        matmul_nn(&dq, &block.wq, n, d, d, &mut dy);
        matmul_nn_acc(&dkb, &block.wk, n, d, d, &mut dy);
        matmul_nn_acc(&dvb, &block.wv, n, d, d, &mut dy);
        {
            let y_l = &y1[l * per..(l + 1) * per];
            matmul_tn_acc(&dq, y_l, n, d, d, bg.wq);
            matmul_tn_acc(&dkb, y_l, n, d, d, bg.wk);
            matmul_tn_acc(&dvb, y_l, n, d, d, bg.wv);
        }
        bias_grad_acc(&dq, bg.bq);
        bias_grad_acc(&dkb, bg.bk);
        bias_grad_acc(&dvb, bg.bv);
        layer_norm_backward(
            &h[l * per..(l + 1) * per],
            d,
            &block.ln1_g,
            &dy,
            &mut dtmp,
            bg.ln1_g,
            bg.ln1_b,
        );
        // ∂L/∂h_l = residual passthrough + LN1 path.
        for (dhv, &tv) in dh.iter_mut().zip(dtmp.iter()) {
            *dhv += tv;
        }
    }
    // Embedding backward: scatter-add rows into the token table, add
    // one-to-one into the positional table.
    for (t, (&tok, drow)) in tokens.iter().zip(dh.chunks_exact(d)).enumerate() {
        let tok = tok as usize;
        for (g, &dv) in gv.tok_emb[tok * d..(tok + 1) * d].iter_mut().zip(drow) {
            *g += dv;
        }
        for (g, &dv) in gv.pos_emb[t * d..(t + 1) * d].iter_mut().zip(drow) {
            *g += dv;
        }
    }

    ws.give_f32("train.h", h);
    ws.give_f32("train.mid", mid);
    ws.give_f32("train.y1", y1);
    ws.give_f32("train.qkv", qkv);
    ws.give_f32("train.attn", attn);
    ws.give_f32("train.ln2", ln2);
    ws.give_f32("train.hpre", hpre);
    ws.give_f32("train.hpost", hpost);
    ws.give_f32("train.lnf", lnf);
    ws.give_f32("train.mean", mean);
    ws.give_f32("train.logits", logits);
    ws.give_f32("train.proj", proj);
    ws.give_f32("train.dlogits", dlogits);
    ws.give_f32("train.dmean", dmean);
    ws.give_f32("train.dlnf", dlnf);
    ws.give_f32("train.dh", dh);
    ws.give_f32("train.dtmp", dtmp);
    ws.give_f32("train.dhid", dhid);
    ws.give_f32("train.dhid2", dhid2);
    ws.give_f32("train.dln2", dln2);
    ws.give_f32("train.dattn", dattn);
    ws.give_f32("train.dq", dq);
    ws.give_f32("train.dk", dkb);
    ws.give_f32("train.dv", dvb);
    ws.give_f32("train.dy", dy);
    (loss, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};
    use crate::model::ModelConfig;

    fn tiny_model(kernel: &str, seed: u64) -> MitaModel {
        MitaModel::init(ModelConfig::new(7, 10, 8, 2, 2, 12, 3, kernel), seed).unwrap()
    }

    fn tiny_batch(model: &MitaModel, batch: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let cfg = &model.cfg;
        let mut rng = Rng::new(seed);
        let tokens =
            (0..batch * cfg.seq_len).map(|_| rng.below(cfg.vocab) as i32).collect();
        let labels = (0..batch).map(|_| rng.below(cfg.classes) as i32).collect();
        (tokens, labels)
    }

    #[test]
    fn batch_loss_matches_serial_single_examples() {
        for kernel in [OP_ATTN_MITA, OP_ATTN_DENSE] {
            let model = tiny_model(kernel, 5);
            let cfg = &model.cfg;
            let (tokens, labels) = tiny_batch(&model, 4, 1);
            let pool = WorkspacePool::new();
            let mut scratch = TrainScratch::default();
            let mut grads = Gradients::zeros(cfg);
            let mut stats = MitaStats::default();
            let out = loss_and_gradients(
                &model, &tokens, &labels, 4, &pool, &mut scratch, &mut grads, &mut stats,
            )
            .unwrap();
            assert_eq!(out.examples, 4);
            assert!(out.loss.is_finite() && out.loss > 0.0);

            // Mean of four single-example batches must agree exactly:
            // the per-example computation is identical and the reduction
            // is a fixed-order sum.
            let mut sum_flat = vec![0.0f32; cfg.param_count()];
            let mut sum_loss = 0.0f64;
            for i in 0..4 {
                let mut g1 = Gradients::zeros(cfg);
                let o1 = loss_and_gradients(
                    &model,
                    &tokens[i * cfg.seq_len..(i + 1) * cfg.seq_len],
                    &labels[i..i + 1],
                    1,
                    &pool,
                    &mut scratch,
                    &mut g1,
                    &mut stats,
                )
                .unwrap();
                sum_loss += o1.loss;
                for (s, &g) in sum_flat.iter_mut().zip(g1.as_slice()) {
                    *s += g;
                }
            }
            assert!((out.loss - sum_loss / 4.0).abs() < 1e-12, "{kernel}: loss mismatch");
            for (i, (&g, &s)) in grads.as_slice().iter().zip(&sum_flat).enumerate() {
                assert!(
                    (g - s / 4.0).abs() <= 1e-6 * (1.0 + s.abs()),
                    "{kernel}: grad {i}: batched {g} vs mean-of-singles {}",
                    s / 4.0
                );
            }
        }
    }

    #[test]
    fn tape_forward_loss_matches_inference_forward_exactly() {
        // The training-time tape forward must compute the *same function*
        // the inference/serving forward runs: same helpers, same op
        // order, bit-identical logits — so the mean training loss equals
        // the f64 cross-entropy of `MitaModel::forward`'s logits exactly.
        // This pins the two forwards against silent drift.
        for kernel in [OP_ATTN_MITA, OP_ATTN_DENSE] {
            let model = tiny_model(kernel, 13);
            let (tokens, labels) = tiny_batch(&model, 3, 9);
            let pool = WorkspacePool::new();
            let mut scratch = TrainScratch::default();
            let mut grads = Gradients::zeros(&model.cfg);
            let mut stats = MitaStats::default();
            let out = loss_and_gradients(
                &model, &tokens, &labels, 3, &pool, &mut scratch, &mut grads, &mut stats,
            )
            .unwrap();

            let registry = model.registry();
            let mut mscratch = crate::model::ModelScratch::default();
            let logits = model
                .forward(&tokens, 3, 3, &registry, &pool, &mut mscratch, &mut stats)
                .unwrap();
            let classes = model.cfg.classes;
            let mut want = 0.0f64;
            for (row, &y) in logits.chunks_exact(classes).zip(&labels) {
                want += crate::train::backward::softmax_xent_loss(row, y as usize);
            }
            want /= 3.0;
            assert_eq!(
                out.loss.to_bits(),
                want.to_bits(),
                "{kernel}: training forward drifted from the inference forward"
            );
        }
    }

    #[test]
    fn gradients_are_finite_and_mostly_nonzero() {
        let model = tiny_model(OP_ATTN_MITA, 9);
        let (tokens, labels) = tiny_batch(&model, 3, 2);
        let pool = WorkspacePool::new();
        let mut scratch = TrainScratch::default();
        let mut grads = Gradients::zeros(&model.cfg);
        let mut stats = MitaStats::default();
        loss_and_gradients(
            &model, &tokens, &labels, 3, &pool, &mut scratch, &mut grads, &mut stats,
        )
        .unwrap();
        assert!(grads.as_slice().iter().all(|g| g.is_finite()));
        let nonzero = grads.as_slice().iter().filter(|&&g| g != 0.0).count();
        assert!(
            nonzero * 2 > grads.len(),
            "most gradients should be nonzero (got {nonzero}/{})",
            grads.len()
        );
        assert!(stats.queries > 0, "training forward records MiTA routing stats");
    }

    #[test]
    fn rejects_malformed_batches() {
        let model = tiny_model(OP_ATTN_DENSE, 3);
        let (tokens, labels) = tiny_batch(&model, 2, 3);
        let pool = WorkspacePool::new();
        let mut scratch = TrainScratch::default();
        let mut grads = Gradients::zeros(&model.cfg);
        let mut stats = MitaStats::default();
        let mut run = |toks: &[i32], labs: &[i32], b: usize| {
            loss_and_gradients(
                &model, toks, labs, b, &pool, &mut scratch, &mut grads, &mut stats,
            )
            .is_err()
        };
        assert!(run(&tokens[1..], &labels, 2), "wrong token count");
        assert!(run(&tokens, &labels[..1], 2), "wrong label count");
        assert!(run(&tokens, &labels, 0), "empty batch");
        let mut bad = tokens.clone();
        bad[0] = model.cfg.vocab as i32;
        assert!(run(&bad, &labels, 2), "out-of-vocab token");
        let bad_labels = vec![model.cfg.classes as i32; 2];
        assert!(run(&tokens, &bad_labels, 2), "out-of-range label");
    }

    #[test]
    fn steady_state_is_bit_identical_and_alloc_stable() {
        let model = tiny_model(OP_ATTN_MITA, 11);
        let (tokens, labels) = tiny_batch(&model, 3, 7);
        let pool = WorkspacePool::new();
        let mut scratch = TrainScratch::default();
        let mut grads = Gradients::zeros(&model.cfg);
        let mut stats = MitaStats::default();
        let run = |scratch: &mut TrainScratch, grads: &mut Gradients, stats: &mut MitaStats| {
            loss_and_gradients(&model, &tokens, &labels, 3, &pool, scratch, grads, stats)
                .unwrap()
        };
        let first = run(&mut scratch, &mut grads, &mut stats);
        let first_flat = grads.as_slice().to_vec();
        for _ in 0..3 {
            let again = run(&mut scratch, &mut grads, &mut stats);
            assert_eq!(again.loss.to_bits(), first.loss.to_bits());
            assert_eq!(grads.as_slice(), first_flat.as_slice());
        }
        // created() counts peak concurrent demand: bounded by the batch
        // (one workspace per in-flight example), not the step count.
        assert!(pool.created() >= 1 && pool.created() <= 3, "created {}", pool.created());
    }
}
