//! Finite-difference gradient checking for the exact backward passes.
//!
//! Central differences: for a scalar loss `f` over f32 inputs, the
//! numeric derivative at coordinate `i` is `(f(x+ε) − f(x−ε)) / 2ε` with
//! the quotient taken in f64. The comparison criterion is the standard
//! relative form `|a − n| ≤ tol · max(1, |a|, |n|)` — an absolute floor
//! of `tol` for small gradients (where f32 forward round-off dominates
//! the quotient) and a relative bound elsewhere. The MiTA kernel is
//! checked under its straight-through convention: the numeric side must
//! evaluate a *frozen-selection* forward (see `docs/TRAINING.md` and the
//! tests in `rust/tests/train_native.rs`), because the analytic backward
//! deliberately assigns no gradient to the selection logits.

use anyhow::Result;

/// Gradient-check settings.
#[derive(Debug, Clone, Copy)]
pub struct CheckOpts {
    /// Central-difference step applied to the f32 input.
    pub eps: f32,
    /// Acceptance threshold for `rel_err`.
    pub tol: f64,
    /// Check every `stride`-th coordinate (1 = all); the first and last
    /// coordinate are always included so boundaries stay covered.
    pub stride: usize,
}

impl Default for CheckOpts {
    fn default() -> Self {
        CheckOpts { eps: 1e-2, tol: 1e-3, stride: 1 }
    }
}

impl CheckOpts {
    /// Default tolerances, checking every `stride`-th coordinate.
    pub fn strided(stride: usize) -> Self {
        CheckOpts { stride: stride.max(1), ..CheckOpts::default() }
    }
}

/// Central difference of `f` along coordinate `i` of `x`.
pub fn central_diff<F>(x: &[f32], i: usize, eps: f32, f: &mut F) -> f64
where
    F: FnMut(&[f32]) -> f64,
{
    let mut xp = x.to_vec();
    xp[i] = x[i] + eps;
    let fp = f(&xp);
    xp[i] = x[i] - eps;
    let fm = f(&xp);
    (fp - fm) / (2.0 * eps as f64)
}

/// `|a − n| / max(1, |a|, |n|)` — relative error with an absolute floor.
pub fn rel_err(analytic: f64, numeric: f64) -> f64 {
    (analytic - numeric).abs() / analytic.abs().max(numeric.abs()).max(1.0)
}

/// Compare an analytic gradient against central differences of `f` over
/// a strided coordinate sample of `x`. Returns the worst relative error,
/// or an error naming the worst offending coordinate when it exceeds
/// `opts.tol`.
pub fn check<F>(label: &str, x: &[f32], analytic: &[f32], opts: &CheckOpts, f: &mut F) -> Result<f64>
where
    F: FnMut(&[f32]) -> f64,
{
    assert_eq!(x.len(), analytic.len(), "{label}: gradient length mismatch");
    assert!(!x.is_empty(), "{label}: empty input");
    let stride = opts.stride.max(1);
    let mut worst = 0.0f64;
    let mut worst_at = 0usize;
    let mut coords: Vec<usize> = (0..x.len()).step_by(stride).collect();
    if *coords.last().unwrap() != x.len() - 1 {
        coords.push(x.len() - 1);
    }
    for i in coords {
        let numeric = central_diff(x, i, opts.eps, f);
        let e = rel_err(analytic[i] as f64, numeric);
        if e > worst {
            worst = e;
            worst_at = i;
        }
    }
    anyhow::ensure!(
        worst <= opts.tol,
        "{label}: gradient check failed at coordinate {worst_at}: analytic {}, numeric {}, \
         rel err {worst:.3e} > tol {:.1e}",
        analytic[worst_at],
        central_diff(x, worst_at, opts.eps, f),
        opts.tol
    );
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_passes_and_wrong_gradient_fails() {
        // f(x) = Σ x², ∇f = 2x — exactly representable, so even loose
        // steps agree tightly.
        let x = vec![0.5f32, -1.25, 2.0, 0.0];
        let grad: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
        let mut f = |xs: &[f32]| xs.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let worst = check("quadratic", &x, &grad, &CheckOpts::default(), &mut f).unwrap();
        assert!(worst < 1e-4, "worst {worst}");

        let mut wrong = grad.clone();
        wrong[1] += 0.5;
        assert!(check("wrong", &x, &wrong, &CheckOpts::default(), &mut f).is_err());
    }

    #[test]
    fn strided_sampling_still_covers_endpoints() {
        let x = vec![1.0f32; 10];
        let mut grad = vec![2.0f32; 10];
        grad[9] = 99.0; // corrupt the last coordinate only
        let mut f = |xs: &[f32]| xs.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let err = check("tail", &x, &grad, &CheckOpts::strided(4), &mut f).unwrap_err();
        assert!(err.to_string().contains("coordinate 9"), "{err}");
    }

    #[test]
    fn rel_err_has_absolute_floor() {
        assert!(rel_err(0.0, 5e-4) < 1e-3, "small-gradient noise tolerated");
        assert!(rel_err(10.0, 10.1) < 2e-2);
        assert!((rel_err(2.0, 1.0) - 0.5).abs() < 1e-12);
    }
}
