//! `NativeTrainer` — the end-to-end native training loop over LRA tasks.
//!
//! One step: draw a deterministic minibatch from the task's train split
//! (its own `data::rng` stream, keyed by the trainer seed and step
//! index), compute mean loss + exact gradients
//! ([`crate::train::model_grad::loss_and_gradients`] — per-example data
//! parallelism with a fixed reduction order), and apply one [`AdamW`]
//! update. Periodic evaluation runs the *inference* forward
//! ([`MitaModel::forward`]) over the val split — the same code path
//! serving executes — so a saved checkpoint reproduces the trainer's
//! eval logits exactly when reloaded through
//! `NativeBackend`/`BindCheckpoint`. Checkpoints go through
//! [`crate::coordinator::checkpoint`]'s container, so
//! `serve --workload model` and `model-check` consume training output
//! unchanged.
//!
//! Training history reuses [`StepRecord`] and evaluation reuses
//! [`EvalResult`] from the coordinator layer, so reporting code works
//! on both the PJRT-artifact driver ([`crate::coordinator::Trainer`])
//! and this native path.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::metrics::Streaming;
use crate::coordinator::trainer::{EvalResult, StepRecord};
use crate::data::lra::{self, SeqTask};
use crate::data::rng::Rng;
use crate::data::Split;
use crate::kernels::api::KernelRegistry;
use crate::kernels::workspace::WorkspacePool;
use crate::kernels::MitaStats;
use crate::model::{MitaModel, ModelScratch};
use crate::train::backward::{softmax_xent_loss, AttnKind};
use crate::train::grads::Gradients;
use crate::train::model_grad::{argmax, loss_and_gradients, TrainScratch};
use crate::train::optim::{AdamW, AdamWConfig};

/// Stream tag separating minibatch sampling from every other
/// `Rng::derive` consumer.
const STREAM_MINIBATCH: u64 = 0x7472_4149;

/// Settings of one [`NativeTrainer::train`] run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer steps to take.
    pub steps: usize,
    /// Examples per minibatch.
    pub batch: usize,
    /// Evaluate every this many steps (0 = only the final eval).
    pub eval_every: usize,
    /// Val-split batches per evaluation.
    pub eval_batches: usize,
    /// Log a line every this many steps (0 = silent).
    pub log_every: usize,
    /// Save the best-eval-loss model here (the final eval participates,
    /// so a configured path is always written).
    pub checkpoint: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            batch: 8,
            eval_every: 25,
            eval_batches: 4,
            log_every: 0,
            checkpoint: None,
        }
    }
}

/// Summary of one training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Steps taken in this run.
    pub steps: usize,
    /// Loss of the run's first step.
    pub first_loss: f64,
    /// Loss of the run's last step.
    pub final_loss: f64,
    /// Mean loss over the run's final quarter (robust convergence
    /// summary, mirrors the PJRT driver's `tail_loss`).
    pub tail_loss: f64,
    /// Evaluation after the last step.
    pub final_eval: EvalResult,
    /// Best evaluation seen (lowest val loss, final included).
    pub best_eval: EvalResult,
    /// Mean wall-clock per step over the run.
    pub mean_step_secs: f64,
}

/// Native training loop: model + optimizer + reusable step buffers.
pub struct NativeTrainer {
    model: MitaModel,
    registry: KernelRegistry,
    pool: WorkspacePool,
    opt: AdamW,
    grads: Gradients,
    scratch: TrainScratch,
    eval_scratch: ModelScratch,
    stats: MitaStats,
    eval_stats: MitaStats,
    seed: u64,
    /// One record per optimizer step taken (across `train` calls).
    pub history: Vec<StepRecord>,
}

impl NativeTrainer {
    /// Build a trainer around `model`. Fails early if the model config is
    /// invalid or any block's kernel has no native backward.
    pub fn new(model: MitaModel, optim: AdamWConfig, seed: u64) -> Result<Self> {
        model.cfg.validate()?;
        for name in &model.cfg.block_kernels {
            AttnKind::from_name(name)?;
        }
        let registry = model.registry();
        let opt = AdamW::new(model.cfg.param_count(), optim);
        let grads = Gradients::zeros(&model.cfg);
        Ok(NativeTrainer {
            model,
            registry,
            pool: WorkspacePool::new(),
            opt,
            grads,
            scratch: TrainScratch::default(),
            eval_scratch: ModelScratch::default(),
            stats: MitaStats::default(),
            eval_stats: MitaStats::default(),
            seed,
            history: Vec::new(),
        })
    }

    /// The model being trained.
    pub fn model(&self) -> &MitaModel {
        &self.model
    }

    /// Consume the trainer, keeping the trained model.
    pub fn into_model(self) -> MitaModel {
        self.model
    }

    /// Optimizer steps taken.
    pub fn steps_taken(&self) -> usize {
        self.opt.steps()
    }

    /// MiTA routing statistics accumulated across *training* forwards
    /// only — evaluation traffic lands in its own accumulator so this
    /// metric is invariant to `eval_every` / `eval_batches`.
    pub fn mita_stats(&self) -> &MitaStats {
        &self.stats
    }

    /// MiTA routing statistics accumulated across evaluation forwards.
    pub fn eval_mita_stats(&self) -> &MitaStats {
        &self.eval_stats
    }

    /// The deterministic minibatch of training step `step`: `batch`
    /// sample indices drawn from `Rng::derive(seed, [tag, step])`, so any
    /// step's batch can be regenerated independently of the others.
    pub fn minibatch(
        &self,
        task: &dyn SeqTask,
        batch: usize,
        step: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let n = task.seq_len();
        let mut rng = Rng::derive(self.seed, &[STREAM_MINIBATCH, step as u64]);
        let mut tokens = Vec::with_capacity(batch * n);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (toks, label) = task.sample(Split::Train, rng.next_u64());
            debug_assert_eq!(toks.len(), n);
            tokens.extend_from_slice(&toks);
            labels.push(label);
        }
        (tokens, labels)
    }

    /// The model must be able to embed the task's tokens and score its
    /// classes; checked once per run for a readable error.
    fn check_task(&self, task: &dyn SeqTask) -> Result<()> {
        let cfg = &self.model.cfg;
        anyhow::ensure!(
            task.seq_len() == cfg.seq_len,
            "task seq_len {} != model seq_len {}",
            task.seq_len(),
            cfg.seq_len
        );
        anyhow::ensure!(
            task.vocab() <= cfg.vocab,
            "model vocab {} cannot embed task vocab {}",
            cfg.vocab,
            task.vocab()
        );
        anyhow::ensure!(
            task.classes() == cfg.classes,
            "task classes {} != model classes {}",
            task.classes(),
            cfg.classes
        );
        Ok(())
    }

    /// One optimizer step on the next deterministic minibatch.
    pub fn step(&mut self, task: &dyn SeqTask, batch: usize) -> Result<StepRecord> {
        self.check_task(task)?;
        let t0 = Instant::now();
        let (tokens, labels) = self.minibatch(task, batch, self.history.len());
        let out = loss_and_gradients(
            &self.model,
            &tokens,
            &labels,
            batch,
            &self.pool,
            &mut self.scratch,
            &mut self.grads,
            &mut self.stats,
        )?;
        self.opt.step(&mut self.model.params, &mut self.grads);
        let rec = StepRecord {
            step: self.history.len(),
            loss: out.loss,
            batch_acc: out.accuracy(),
            secs: t0.elapsed().as_secs_f64(),
        };
        self.history.push(rec.clone());
        Ok(rec)
    }

    /// Evaluate on the task's val split through the *inference* forward —
    /// the exact code path serving runs, so checkpoint reloads reproduce
    /// these logits bit-for-bit.
    pub fn eval(&mut self, task: &dyn SeqTask, batches: usize, batch: usize) -> Result<EvalResult> {
        self.check_task(task)?;
        anyhow::ensure!(batches >= 1 && batch >= 1, "empty evaluation");
        let classes = self.model.cfg.classes;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut examples = 0usize;
        for b in 0..batches {
            let start = (b * batch) as u64;
            let (tokens, labels) = lra::batch_host(task, Split::Val, start, batch);
            let logits = self.model.forward(
                &tokens,
                batch,
                batch,
                &self.registry,
                &self.pool,
                &mut self.eval_scratch,
                &mut self.eval_stats,
            )?;
            for (row, &y) in logits.chunks_exact(classes).zip(&labels) {
                loss += softmax_xent_loss(row, y as usize);
                correct += (argmax(row) == y as usize) as usize;
            }
            examples += batch;
        }
        Ok(EvalResult {
            loss: loss / examples as f64,
            accuracy: correct as f64 / examples as f64,
            miou: None,
            examples,
        })
    }

    /// Run the full loop: steps + periodic eval + best-checkpoint save.
    pub fn train(&mut self, task: &dyn SeqTask, cfg: &TrainConfig) -> Result<TrainOutcome> {
        self.check_task(task)?;
        anyhow::ensure!(cfg.steps >= 1 && cfg.batch >= 1, "degenerate training run");
        let run_start = self.history.len();
        let mut best: Option<EvalResult> = None;
        for s in 0..cfg.steps {
            let rec = self.step(task, cfg.batch)?;
            if cfg.log_every > 0 && (s + 1) % cfg.log_every == 0 {
                eprintln!(
                    "[train-native] step {:4}/{} loss={:.4} batch_acc={:.3}",
                    s + 1,
                    cfg.steps,
                    rec.loss,
                    rec.batch_acc
                );
            }
            if cfg.eval_every > 0 && (s + 1) % cfg.eval_every == 0 && s + 1 < cfg.steps {
                let ev = self.eval(task, cfg.eval_batches.max(1), cfg.batch)?;
                if cfg.log_every > 0 {
                    eprintln!(
                        "[train-native] eval @ step {}: loss={:.4} acc={:.3}",
                        s + 1,
                        ev.loss,
                        ev.accuracy
                    );
                }
                self.keep_best(&mut best, ev, cfg)?;
            }
        }
        let final_eval = self.eval(task, cfg.eval_batches.max(1), cfg.batch)?;
        self.keep_best(&mut best, final_eval.clone(), cfg)?;
        let run = &self.history[run_start..];
        let tail = &run[run.len() - (run.len() / 4).max(1)..];
        let mut secs = Streaming::default();
        for r in run {
            secs.push(r.secs);
        }
        Ok(TrainOutcome {
            steps: run.len(),
            first_loss: run[0].loss,
            final_loss: run[run.len() - 1].loss,
            tail_loss: tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64,
            final_eval,
            best_eval: best.expect("final eval always participates"),
            mean_step_secs: secs.mean(),
        })
    }

    /// Track the lowest-val-loss eval, checkpointing the current model
    /// whenever it improves.
    fn keep_best(
        &self,
        best: &mut Option<EvalResult>,
        ev: EvalResult,
        cfg: &TrainConfig,
    ) -> Result<()> {
        let improved = best.as_ref().map(|b| ev.loss < b.loss).unwrap_or(true);
        if improved {
            if let Some(path) = &cfg.checkpoint {
                self.model.save(path)?;
            }
            *best = Some(ev);
        }
        Ok(())
    }
}

/// `(step, loss)` pairs for [`crate::harness::figures::loss_curve_chart`].
pub fn loss_curve(history: &[StepRecord]) -> Vec<(f64, f64)> {
    history.iter().map(|r| (r.step as f64, r.loss)).collect()
}

/// Format a number for hand-rolled JSON: non-finite values (a diverged
/// run's NaN loss) become `null` so the artifact stays parseable.
pub fn json_num(x: f64, prec: usize) -> String {
    if x.is_finite() {
        format!("{x:.prec$}")
    } else {
        "null".into()
    }
}

/// Deterministic JSON for `--curve-out`: one record per step.
pub fn curve_json(history: &[StepRecord]) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\n  \"records\": [\n");
    for (i, r) in history.iter().enumerate() {
        let comma = if i + 1 < history.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"step\": {}, \"loss\": {}, \"batch_acc\": {}, \"secs\": {}}}{comma}",
            r.step,
            json_num(r.loss, 6),
            json_num(r.batch_acc, 4),
            json_num(r.secs, 6)
        );
    }
    let _ = writeln!(out, "  ],");
    let _ = writeln!(out, "  \"steps\": {}", history.len());
    out.push('}');
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{OP_ATTN_DENSE, OP_ATTN_MITA};
    use crate::model::ModelConfig;

    fn tiny_task() -> Box<dyn SeqTask> {
        lra::by_name("listops", 32, 16, 7)
    }

    fn tiny_trainer(kernel: &str) -> NativeTrainer {
        let task = tiny_task();
        let cfg = ModelConfig::for_task(task.as_ref(), 16, 2, 1, kernel);
        let model = MitaModel::init(cfg, 3).unwrap();
        NativeTrainer::new(model, AdamWConfig::default(), 5).unwrap()
    }

    #[test]
    fn minibatches_are_deterministic_per_step_and_differ_across_steps() {
        let trainer = tiny_trainer(OP_ATTN_MITA);
        let task = tiny_task();
        let a = trainer.minibatch(task.as_ref(), 4, 0);
        let b = trainer.minibatch(task.as_ref(), 4, 0);
        assert_eq!(a, b, "same step must yield the same batch");
        let c = trainer.minibatch(task.as_ref(), 4, 1);
        assert_ne!(a.0, c.0, "different steps draw different batches");
        assert_eq!(a.0.len(), 4 * 32);
        assert_eq!(a.1.len(), 4);
    }

    #[test]
    fn step_records_history_and_eval_is_finite() {
        let mut trainer = tiny_trainer(OP_ATTN_DENSE);
        let task = tiny_task();
        let r0 = trainer.step(task.as_ref(), 4).unwrap();
        let r1 = trainer.step(task.as_ref(), 4).unwrap();
        assert_eq!((r0.step, r1.step), (0, 1));
        assert_eq!(trainer.history.len(), 2);
        assert_eq!(trainer.steps_taken(), 2);
        assert!(r0.loss.is_finite() && r1.loss.is_finite());
        let ev = trainer.eval(task.as_ref(), 2, 4).unwrap();
        assert!(ev.loss.is_finite() && ev.loss > 0.0);
        assert_eq!(ev.examples, 8);
        assert!(ev.miou.is_none());
    }

    #[test]
    fn eval_stats_do_not_contaminate_training_stats() {
        let mut trainer = tiny_trainer(OP_ATTN_MITA);
        let task = tiny_task();
        trainer.step(task.as_ref(), 4).unwrap();
        let train_q = trainer.mita_stats().queries;
        assert!(train_q > 0, "training forward must record routing stats");
        trainer.eval(task.as_ref(), 2, 4).unwrap();
        assert_eq!(
            trainer.mita_stats().queries,
            train_q,
            "eval traffic must not leak into the training accumulator"
        );
        assert!(trainer.eval_mita_stats().queries > 0, "eval stats land in their own bucket");
    }

    #[test]
    fn rejects_mismatched_tasks_and_untrainable_kernels() {
        let trainer = tiny_trainer(OP_ATTN_MITA);
        let wrong_len = lra::by_name("listops", 64, 16, 7);
        assert!(trainer.check_task(wrong_len.as_ref()).is_err());

        let task = tiny_task();
        let cfg = ModelConfig::for_task(task.as_ref(), 16, 2, 1, OP_ATTN_MITA);
        let model = MitaModel::init(cfg, 1).unwrap();
        // An unknown kernel name fails at construction, not mid-training.
        let mut bad_cfg = model.cfg.clone();
        bad_cfg.block_kernels[0] = "attn.unknown".into();
        let bad = MitaModel { cfg: bad_cfg, params: model.params.clone() };
        assert!(NativeTrainer::new(bad, AdamWConfig::default(), 0).is_err());
    }

    #[test]
    fn curve_helpers_render_every_step() {
        let history = vec![
            StepRecord { step: 0, loss: 2.0, batch_acc: 0.25, secs: 0.01 },
            StepRecord { step: 1, loss: 1.5, batch_acc: 0.5, secs: 0.01 },
        ];
        assert_eq!(loss_curve(&history), vec![(0.0, 2.0), (1.0, 1.5)]);
        let json = curve_json(&history);
        assert!(json.contains("\"steps\": 2"));
        assert!(json.contains("\"loss\": 1.500000"));
        assert!(json.ends_with("}\n"));

        // A diverged run's NaN loss must not corrupt the artifact.
        let bad = vec![StepRecord { step: 0, loss: f64::NAN, batch_acc: 0.0, secs: 0.01 }];
        let json = curve_json(&bad);
        assert!(json.contains("\"loss\": null"), "{json}");
        assert!(!json.contains("NaN"));
        assert_eq!(json_num(1.25, 2), "1.25");
        assert_eq!(json_num(f64::INFINITY, 2), "null");
    }
}
