"""AOT pipeline tests: flat signatures, manifest consistency, HLO-text
interchange validity (parseable header, no post-0.5.1 instructions)."""

import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import AttentionConfig, ModelConfig, TrainConfig
from compile.specs import Bundle, all_bundles


def tiny_bundle():
    mc = ModelConfig(
        task="cls_image",
        depth=1,
        dim=32,
        heads=2,
        num_classes=4,
        image_hw=(8, 8),
        patch=4,
        channels=1,
        attention=AttentionConfig(kind="mita", m=2, k=2, landmark="pool2d"),
    )
    tc = TrainConfig(batch_size=2, warmup_steps=1, total_steps=4)
    return Bundle(name="tiny", model=mc, train=tc, emit=("init", "train_step", "eval_step", "predict"))


def test_bundle_registry_consistent():
    bundles = all_bundles()
    names = [b.name for b in bundles]
    assert len(names) == len(set(names))
    # Every referenced warm-start bundle exists.
    byname = {b.name: b for b in bundles}
    for b in bundles:
        ws = b.meta.get("warm_start") or b.meta.get("trained_on")
        if ws:
            assert ws in byname, f"{b.name} references missing bundle {ws}"
    # Swap-eval bundles share param layout with their training source.
    for b in bundles:
        src = b.meta.get("trained_on")
        if src:
            src_layout = aot.param_layout(byname[src].model)
            assert aot.param_layout(b.model) == src_layout, (b.name, src)


def test_flat_signatures_roundtrip():
    b = tiny_bundle()
    p_n = len(aot.param_layout(b.model))

    init_fn, init_args = aot.build_fn(b, "init")
    state = init_fn(jnp.int32(0))
    assert len(state) == 3 * p_n + 1

    train_fn, train_args = aot.build_fn(b, "train_step")
    assert len(train_args) == 3 * p_n + 3
    x = jnp.zeros((2, 8, 8, 1), jnp.float32)
    y = jnp.zeros((2,), jnp.int32)
    out = train_fn(*state[: 3 * p_n], state[3 * p_n], x, y)
    assert len(out) == 3 * p_n + 3
    loss, correct = float(out[-2]), int(out[-1])
    assert np.isfinite(loss)
    assert int(out[3 * p_n]) == 1  # step incremented

    eval_fn, eval_args = aot.build_fn(b, "eval_step")
    assert len(eval_args) == p_n + 2
    loss, correct = eval_fn(*state[:p_n], x, y)
    assert np.isfinite(float(loss))

    pred_fn, pred_args = aot.build_fn(b, "predict")
    (logits,) = pred_fn(*state[:p_n], x)
    assert logits.shape == (2, 4)


def test_hlo_text_is_legacy_parseable():
    """The interchange contract: no `topk` instruction, no
    operand_batching_dims-style gathers, parseable ENTRY header."""
    b = tiny_bundle()
    for which in ("init", "train_step", "eval_step", "predict"):
        fn, fargs = aot.build_fn(b, which)
        lowered = jax.jit(fn).lower(*fargs)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, which
        assert re.search(r"\btopk\(", text) is None, f"{which} contains topk instruction"
        assert "largest=" not in text, which
        assert "operand_batching_dims" not in text, which


def test_spec_hash_stability():
    b = tiny_bundle()
    h1 = aot.spec_hash(b, "train_step")
    h2 = aot.spec_hash(b, "train_step")
    assert h1 == h2
    assert aot.spec_hash(b, "init") != h1


def test_param_layout_paths_unique():
    b = tiny_bundle()
    layout = aot.param_layout(b.model)
    paths = [p["path"] for p in layout]
    assert len(paths) == len(set(paths))
    for p in layout:
        assert p["dtype"] in ("f32", "i32")


def test_emit_bundle_and_manifest(tmp_path):
    b = tiny_bundle()
    manifest = {"version": aot.MANIFEST_VERSION}
    n = aot.emit_bundle(b, tmp_path, manifest)
    assert n == 4
    # Cached second run lowers nothing.
    assert aot.emit_bundle(b, tmp_path, manifest) == 0
    entry = manifest["bundles"]["tiny"]
    assert set(entry["artifacts"]) == {"init", "train_step", "eval_step", "predict"}
    for name in entry["artifacts"].values():
        art = manifest["artifacts"][name]
        assert (tmp_path / art["file"]).exists()
        assert art["inputs"] and art["outputs"]
    # Manifest is valid JSON end-to-end.
    text = json.dumps(manifest)
    assert json.loads(text)["bundles"]["tiny"]["model"]["dim"] == 32


def test_batch_specs_match_tasks():
    b = tiny_bundle()
    x, y = aot._batch_specs(b.model, 3)
    assert x.shape == (3, 8, 8, 1) and y.shape == (3,)
    lra = ModelConfig(
        task="lra", depth=1, dim=32, heads=2, num_classes=2, seq_len=16, vocab=8,
        attention=AttentionConfig(kind="mita", m=2, k=2, landmark="pool1d"),
    )
    x, y = aot._batch_specs(lra, 3)
    assert x.shape == (3, 16) and x.dtype == jnp.int32
    seg = ModelConfig(
        task="seg_image", depth=1, dim=32, heads=2, num_classes=4, image_hw=(8, 8),
        patch=4, channels=1,
        attention=AttentionConfig(kind="mita", m=2, k=2, landmark="pool2d"),
    )
    x, y = aot._batch_specs(seg, 2)
    assert y.shape == (2, 4)
