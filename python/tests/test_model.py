"""L2 model tests: shapes, training dynamics, swap-compatibility."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import AttentionConfig, ModelConfig, TrainConfig
from compile import model

ALL_KINDS = ["standard", "linear", "agent", "mita", "mita_route", "mita_compress"]


def img_cfg(kind="mita", **kw):
    return ModelConfig(
        task="cls_image",
        depth=2,
        dim=64,
        heads=4,
        num_classes=10,
        image_hw=(16, 16),
        patch=4,
        channels=3,
        attention=AttentionConfig(kind=kind, m=4, k=4, landmark="pool2d"),
        **kw,
    )


def img_batch(b=4, cfg=None, seed=0):
    cfg = cfg or img_cfg()
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, *cfg.image_hw, cfg.channels))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, cfg.num_classes)
    return x, y


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_forward_shapes_all_kinds(kind):
    cfg = img_cfg(kind)
    params = model.init_params(jnp.int32(0), cfg)
    x, _ = img_batch(3, cfg)
    logits = model.forward(params, x, cfg)
    assert logits.shape == (3, 10)
    assert np.isfinite(np.array(logits)).all()


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_train_step_all_kinds(kind):
    cfg = img_cfg(kind)
    params = model.init_params(jnp.int32(0), cfg)
    opt = model.init_opt_state(params)
    x, y = img_batch(4, cfg)
    p2, o2, loss, correct = model.train_step(params, opt, x, y, cfg, TrainConfig())
    assert np.isfinite(float(loss))
    assert 0 <= int(correct) <= 4
    assert int(o2["step"]) == 1
    # Parameters actually moved.
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(moved)) > 0


def test_param_layouts_identical_across_kinds():
    """Fig. 9 / Tab. 7 swap experiments require identical parameter trees
    for every non-learned-landmark attention kind."""
    layouts = []
    for kind in ALL_KINDS:
        cfg = img_cfg(kind)
        tmpl = jax.eval_shape(lambda s: model.init_params(s, cfg), jnp.zeros((), jnp.int32))
        flat = jax.tree_util.tree_flatten_with_path(tmpl)[0]
        layouts.append([(jax.tree_util.keystr(p), l.shape, l.dtype) for p, l in flat])
    for other in layouts[1:]:
        assert other == layouts[0]


def test_learned_landmarks_add_param():
    cfg = img_cfg("mita")
    cfg_learned = ModelConfig(
        **{**cfg.__dict__, "attention": AttentionConfig(kind="mita", m=4, k=4, landmark="learned")}
    )
    n_plain = len(jax.tree.leaves(model.init_params(jnp.int32(0), cfg)))
    n_learned = len(jax.tree.leaves(model.init_params(jnp.int32(0), cfg_learned)))
    assert n_learned == n_plain + cfg.depth


def test_loss_decreases_on_fixed_batch():
    """Overfit a single batch: loss after 25 steps must drop substantially."""
    cfg = img_cfg("mita")
    tcfg = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=25, weight_decay=0.0)
    params = model.init_params(jnp.int32(0), cfg)
    opt = model.init_opt_state(params)
    x, y = img_batch(8, cfg)
    step = jax.jit(lambda p, o: model.train_step(p, o, x, y, cfg, tcfg))
    first = None
    for i in range(25):
        params, opt, loss, _ = step(params, opt)
        if i == 0:
            first = float(loss)
    assert float(loss) < first * 0.5, (first, float(loss))


def test_lra_model_and_train():
    cfg = ModelConfig(
        task="lra",
        depth=2,
        dim=32,
        heads=2,
        num_classes=5,
        seq_len=64,
        vocab=16,
        attention=AttentionConfig(kind="mita", m=8, k=8, landmark="pool1d"),
    )
    params = model.init_params(jnp.int32(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, 16)
    y = jnp.array([0, 1, 2, 3], jnp.int32)
    logits = model.forward(params, x, cfg)
    assert logits.shape == (4, 5)
    _, _, loss, _ = model.train_step(params, model.init_opt_state(params), x, y, cfg, TrainConfig())
    assert np.isfinite(float(loss))


def test_seg_model_confusion():
    cfg = ModelConfig(
        task="seg_image",
        depth=2,
        dim=32,
        heads=2,
        num_classes=6,
        image_hw=(16, 16),
        patch=4,
        channels=3,
        attention=AttentionConfig(kind="mita", m=4, k=4, landmark="pool2d"),
    )
    params = model.init_params(jnp.int32(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 3))
    y = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 6)
    logits = model.forward(params, x, cfg)
    assert logits.shape == (2, 16, 6)
    loss, conf = model.eval_step_seg(params, x, y, cfg)
    conf = np.array(conf)
    assert conf.shape == (6, 6)
    # Confusion sums to the number of evaluated tokens.
    assert conf.sum() == 32
    p2, o2, loss, correct = model.train_step_seg(
        params, model.init_opt_state(params), x, y, cfg, TrainConfig()
    )
    assert np.isfinite(float(loss))


def test_dwc_and_gate_variants():
    for kw in [{"dwc": True}, {"gate": True}, {"dwc": True, "gate": True}]:
        cfg = img_cfg("mita", **kw)
        params = model.init_params(jnp.int32(0), cfg)
        x, y = img_batch(2, cfg)
        logits = model.forward(params, x, cfg)
        assert logits.shape == (2, 10)
        _, _, loss, _ = model.train_step(
            params, model.init_opt_state(params), x, y, cfg, TrainConfig()
        )
        assert np.isfinite(float(loss))


def test_pallas_forward_matches_ref_forward():
    """use_pallas=True must agree with the reference forward (inference)."""
    base = img_cfg("mita")
    pallas_cfg = ModelConfig(
        **{
            **base.__dict__,
            "attention": AttentionConfig(kind="mita", m=4, k=4, landmark="pool2d", use_pallas=True, cap_factor=4),
        }
    )
    params = model.init_params(jnp.int32(0), base)
    x, _ = img_batch(2, base)
    a = model.forward(params, x, base)
    b = model.forward(params, x, pallas_cfg)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-4)


def test_analysis_forward_internals():
    cfg = img_cfg("mita")
    params = model.init_params(jnp.int32(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 16, 3))
    logits, idx, assign = model.analysis_forward(params, x, cfg)
    assert logits.shape == (10,)
    assert idx.shape == (cfg.depth, cfg.heads, 4, 4)
    assert assign.shape == (cfg.depth, cfg.heads, cfg.num_tokens)
    assert (np.array(idx) >= 0).all() and (np.array(idx) < cfg.num_tokens).all()
    assert (np.array(assign) >= 0).all() and (np.array(assign) < 4).all()


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(model._lr_schedule(jnp.int32(s), tcfg)) for s in [0, 5, 10, 50, 99]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup rising
    assert lrs[2] > lrs[3] > lrs[4]  # cosine decay
    assert lrs[4] < 0.05


def test_deterministic_init():
    cfg = img_cfg("mita")
    a = model.init_params(jnp.int32(42), cfg)
    b = model.init_params(jnp.int32(42), cfg)
    c = model.init_params(jnp.int32(43), cfg)
    la, lb, lc = (jax.tree.leaves(t) for t in (a, b, c))
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.array(x), np.array(y))
    assert any(not np.array_equal(np.array(x), np.array(y)) for x, y in zip(la, lc))
