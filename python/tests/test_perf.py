"""Structural performance-model tests (L1 §Perf invariants)."""

import pytest

from compile import perf


def test_vmem_within_budget_for_all_experiment_configs():
    for n, d, m, kk in [(196, 64, 25, 25), (64, 16, 16, 16), (512, 32, 32, 32), (4096, 32, 64, 64)]:
        r = perf.mita_kernel_report(n, d, m, kk)
        assert r.vmem_bytes <= perf.VMEM_TARGET, (n, d, m, kk, r.vmem_bytes)
        assert r.fits_target


def test_flash_kernel_vmem_scales_with_blocks():
    small = perf.flash_kernel_report(1024, 64, block_q=64, block_k=64)
    big = perf.flash_kernel_report(1024, 64, block_q=256, block_k=256)
    assert big.vmem_bytes > small.vmem_bytes
    assert big.vmem_bytes <= perf.VMEM_BUDGET


def test_mxu_efficiency_bounds_and_monotonicity():
    assert perf.mxu_efficiency(128, 128, 128) == 1.0
    assert perf.mxu_efficiency(64, 128, 128) == 0.5
    e_small = perf.mxu_efficiency(8, 8, 8)
    e_mid = perf.mxu_efficiency(64, 64, 64)
    assert 0 < e_small < e_mid < 1.0


def test_bigger_block_q_improves_mxu_eff():
    sweep = perf.sweep_block_q(512, 32, 32, 32)
    assert sweep[128]["mxu_eff"] >= sweep[16]["mxu_eff"]
    # But VMEM grows.
    assert sweep[256]["vmem_bytes"] > sweep[16]["vmem_bytes"]


def test_arithmetic_intensity_positive_and_finite():
    r = perf.mita_kernel_report(512, 32, 32, 32)
    assert r.arithmetic_intensity > 0
    d = r.as_dict()
    assert set(d) >= {"vmem_mib", "mxu_eff", "arithmetic_intensity"}


def test_capacity_matches_rust_mirror():
    # Must agree with rust/src/mita/routing.rs::capacity test vectors.
    assert perf._capacity(196, 25, 2, 64) == 64
    assert perf._capacity(1024, 16, 2, 64) == 128
    assert perf._capacity(64, 16, 1, 8) == 8
