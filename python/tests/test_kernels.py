"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
kernels.ref — the CORE correctness signal for the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_kernel
from compile.kernels import mita as mita_kernel
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

TOL = dict(rtol=2e-5, atol=2e-5)
BF16_TOL = dict(rtol=8e-2, atol=8e-2)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def qkv(seed, n, d, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    return tuple(rand(jax.random.fold_in(key, i), (n, d), dtype) for i in range(3))


# ---------------------------------------------------------------------------
# Flash attention kernel vs softmax oracle.
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([16, 32, 49]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_softmax(n_blocks, block, d, seed):
    n = n_blocks * block
    q, k, v = qkv(seed, n, d)
    out = attn_kernel.flash_attention(q, k, v, block_q=block, block_k=block)
    np.testing.assert_allclose(np.array(out), np.array(ref.softmax_attention(q, k, v)), **TOL)


@settings(max_examples=6, deadline=None)
@given(
    g=st.integers(1, 6),
    n=st.sampled_from([32, 64]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_batched_matches(g, n, d, seed):
    key = jax.random.PRNGKey(seed)
    q, k, v = (rand(jax.random.fold_in(key, i), (g, n, d)) for i in range(3))
    out = attn_kernel.flash_attention_b(q, k, v, block_q=32, block_k=32)
    np.testing.assert_allclose(np.array(out), np.array(ref.softmax_attention_b(q, k, v)), **TOL)


def test_flash_attention_extreme_logits_stable():
    # Large-magnitude queries stress the online-softmax rescaling.
    q, k, v = qkv(0, 64, 16)
    out = attn_kernel.flash_attention(q * 30.0, k * 30.0, v, block_q=16, block_k=16)
    expect = ref.softmax_attention(q * 30.0, k * 30.0, v)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=1e-4, atol=1e-4)
    assert np.isfinite(np.array(out)).all()


# ---------------------------------------------------------------------------
# MiTA kernel vs exact reference.
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    n=st.sampled_from([64, 128, 196, 256]),
    d=st.sampled_from([8, 16, 32]),
    m=st.sampled_from([4, 9, 16, 25]),
    kk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mita_pallas_matches_ref(n, d, m, kk, seed):
    q, k, v = qkv(seed, n, d)
    q_land = ref.landmarks_pool1d(q, m)
    expect = ref.mita_attention_ref(q, k, v, q_land, kk)
    out, aux = mita_kernel.mita_attention_pallas(
        q, k, v, q_land, kk, cap_factor=max(4, m), block_q=16, return_aux=True
    )
    # cap_factor is set high enough that no query overflows -> exact.
    assert int(aux["overflow"]) == 0
    np.testing.assert_allclose(np.array(out), np.array(expect), **TOL)


@settings(max_examples=6, deadline=None)
@given(
    g=st.integers(1, 8),
    n=st.sampled_from([64, 128]),
    d=st.sampled_from([8, 16]),
    m=st.sampled_from([8, 16]),
    kk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mita_pallas_batched_matches_vmapped_ref(g, n, d, m, kk, seed):
    key = jax.random.PRNGKey(seed)
    q, k, v = (rand(jax.random.fold_in(key, i), (g, n, d)) for i in range(3))
    q_land = jax.vmap(lambda x: ref.landmarks_pool1d(x, m))(q)
    expect = jax.vmap(lambda a, b, c, l: ref.mita_attention_ref(a, b, c, l, kk))(q, k, v, q_land)
    out = mita_kernel.mita_attention_pallas_b(q, k, v, q_land, kk, cap_factor=max(4, m), block_q=16)
    np.testing.assert_allclose(np.array(out), np.array(expect), **TOL)


def test_mita_batched_ref_matches_single():
    g, n, d, m, kk = 5, 96, 16, 8, 8
    key = jax.random.PRNGKey(3)
    q, k, v = (rand(jax.random.fold_in(key, i), (g, n, d)) for i in range(3))
    q_land = jax.vmap(lambda x: ref.landmarks_pool1d(x, m))(q)
    for s in (1, 2):
        b = ref.mita_attention_ref_b(q, k, v, q_land, kk, s=s)
        single = jax.vmap(lambda a, c, e, l: ref.mita_attention_ref(a, c, e, l, kk, s=s))(
            q, k, v, q_land
        )
        np.testing.assert_allclose(np.array(b), np.array(single), **TOL)


def test_mita_overflow_fallback_is_shared_only():
    """With cap_factor=1 some queries overflow; they must get the
    compress-only output rather than garbage."""
    n, d, m, kk = 128, 16, 4, 8
    # Adversarial routing: all queries prefer one landmark.
    key = jax.random.PRNGKey(7)
    q = jnp.abs(rand(key, (n, d))) + 1.0  # positive -> same argmax direction
    k = rand(jax.random.fold_in(key, 1), (n, d))
    v = rand(jax.random.fold_in(key, 2), (n, d))
    q_land = ref.landmarks_pool1d(q, m)
    out, aux = mita_kernel.mita_attention_pallas(
        q, k, v, q_land, kk, cap_factor=1, block_q=16, return_aux=True
    )
    overflow = int(aux["overflow"])
    assert overflow > 0, "expected overflow under adversarial routing"
    # Overflowed queries match the shared-only (compress-only) reference.
    scores = ref.mita_scores(k, q_land)
    v_land = ref.mita_landmark_values(scores, v)
    shared = jax.nn.softmax((q @ q_land.T) / jnp.sqrt(jnp.float32(d)), axis=-1) @ v_land
    # Identify overflowed queries by comparing against the exact reference.
    exact = ref.mita_attention_ref(q, k, v, q_land, kk)
    mismatch = ~np.isclose(np.array(out), np.array(exact), **TOL).all(axis=-1)
    assert mismatch.sum() == overflow or mismatch.sum() <= overflow
    np.testing.assert_allclose(
        np.array(out)[mismatch], np.array(shared)[mismatch], **TOL
    )


def test_mita_equals_full_attention_when_m_k_cover_n():
    """Paper Sec. A: MiTA recovers full attention as m, k -> N (the routed
    expert alone covers every key-value pair)."""
    n, d = 32, 8
    q, k, v = qkv(11, n, d)
    q_land = ref.landmarks_pool1d(q, 4)
    out = ref.mita_attention_ref(q, k, v, q_land, kk=n, include_shared=False)
    np.testing.assert_allclose(np.array(out), np.array(ref.softmax_attention(q, k, v)), **TOL)


def test_mita_compress_only_equals_agent_attention():
    """Compress-only MiTA == Agent Attention (both are softmax(QQ̃) Ṽ)."""
    n, d, m = 64, 16, 8
    q, k, v = qkv(13, n, d)
    q_land = ref.landmarks_pool1d(q, m)
    a = ref.mita_attention_ref(q, k, v, q_land, kk=4, include_routed=False)
    b = ref.agent_attention(q, k, v, q_land)
    np.testing.assert_allclose(np.array(a), np.array(b), **TOL)


def test_mita_bf16_within_loose_tolerance():
    """bf16 kernel vs bf16 reference. Comparing against an f32 reference is
    ill-posed: bf16 score rounding can flip top-k *membership*, changing
    the output structurally rather than numerically — so the oracle must
    run at the same precision (same selections), and only the attention
    arithmetic tolerance is under test."""
    n, d, m, kk = 128, 16, 8, 8
    q, k, v = qkv(17, n, d, jnp.bfloat16)
    q_land = ref.landmarks_pool1d(q, m)
    out = mita_kernel.mita_attention_pallas(q, k, v, q_land, kk, cap_factor=8, block_q=16)
    expect = ref.mita_attention_ref(q, k, v, q_land, kk)
    np.testing.assert_allclose(
        np.array(out, dtype=np.float32), np.array(expect, dtype=np.float32), **BF16_TOL
    )


# ---------------------------------------------------------------------------
# Online softmax combine (Alg. 1 line 16).
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    n=st.integers(1, 32),
    k1=st.integers(1, 32),
    k2=st.integers(1, 32),
    d=st.sampled_from([4, 16]),
    scale=st.sampled_from([1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_online_softmax_combine_exact(n, k1, k2, d, scale, seed):
    key = jax.random.PRNGKey(seed)
    q = rand(jax.random.fold_in(key, 0), (n, d), scale=scale)
    ka = rand(jax.random.fold_in(key, 1), (k1, d))
    va = rand(jax.random.fold_in(key, 2), (k1, d))
    kb = rand(jax.random.fold_in(key, 3), (k2, d))
    vb = rand(jax.random.fold_in(key, 4), (k2, d))

    o1, m1, l1 = ref.partial_softmax(q, ka, va)
    o2, m2, l2 = ref.partial_softmax(q, kb, vb)
    combined = ref.online_softmax_combine(o1, m1, l1, o2, m2, l2)
    full = ref.softmax_attention(q, jnp.concatenate([ka, kb]), jnp.concatenate([va, vb]))
    np.testing.assert_allclose(np.array(combined), np.array(full), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Landmark extraction.
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(4, 256),
    m_frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool1d_preserves_global_mean(n, m_frac, seed):
    m = max(1, int(n * m_frac))
    q = rand(jax.random.PRNGKey(seed), (n, 8))
    lands = ref.landmarks_pool1d(q, m)
    assert lands.shape == (m, 8)
    if n % m == 0:
        # Equal windows -> pooled mean == global mean.
        np.testing.assert_allclose(
            np.array(lands.mean(0)), np.array(q.mean(0)), rtol=1e-4, atol=1e-5
        )


def test_pool2d_nondivisible_grid():
    # The paper's exact case: 14x14 grid, 5x5 landmarks.
    q = rand(jax.random.PRNGKey(0), (196, 16))
    lands = ref.extract_landmarks(q, "pool2d", 25, grid_hw=(14, 14))
    assert lands.shape == (25, 16)
    # Constant input -> constant landmarks.
    const = ref.extract_landmarks(jnp.ones((196, 16)), "pool2d", 25, grid_hw=(14, 14))
    np.testing.assert_allclose(np.array(const), 1.0, rtol=1e-6)


def test_landmark_modes_shapes():
    q = rand(jax.random.PRNGKey(1), (64, 16))
    for mode, kwargs in [
        ("pool1d", {}),
        ("pool2d", {"grid_hw": (8, 8)}),
        ("random", {}),
        ("learned", {"learned": jnp.zeros((8, 16))}),
    ]:
        lands = ref.extract_landmarks(q, mode, 8, **kwargs)
        assert lands.shape == (8, 16), mode


def test_adaptive_pool_matrix_partition():
    for n, m in [(14, 5), (196, 25), (7, 7), (64, 16), (10, 3)]:
        p = np.array(ref._adaptive_pool_matrix(n, m))
        np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-6)
        # Every column in exactly one window.
        assert ((p > 0).sum(axis=0) == 1).all()


# ---------------------------------------------------------------------------
# Routing / top-k semantics.
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(n=st.integers(8, 128), m=st.integers(1, 8), kk=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_topk_indices_are_true_topk(n, m, kk, seed):
    kk = min(kk, n)
    scores = rand(jax.random.PRNGKey(seed), (n, m))
    idx = np.array(ref.mita_topk_indices(scores, kk))
    s = np.array(scores)
    for i in range(m):
        got = set(idx[i].tolist())
        expect = set(np.argsort(-s[:, i])[:kk].tolist())
        # Ties can differ; compare score multisets instead of indices.
        np.testing.assert_allclose(
            np.sort(s[list(got), i]), np.sort(s[list(expect), i]), rtol=1e-6
        )


def test_routing_argmax_in_range():
    q, k, v = qkv(23, 64, 16)
    q_land = ref.landmarks_pool1d(q, 8)
    e = np.array(ref.mita_routing(q, q_land, 1))
    assert e.shape == (64, 1)
    assert (e >= 0).all() and (e < 8).all()
    e2 = np.array(ref.mita_routing(q, q_land, 3))
    assert e2.shape == (64, 3)
    # Top-s experts are distinct per query.
    for row in e2:
        assert len(set(row.tolist())) == 3


# ---------------------------------------------------------------------------
# Gradients through MiTA (training path).
# ---------------------------------------------------------------------------


def test_mita_ref_is_differentiable():
    g, n, d, m, kk = 2, 32, 8, 4, 4
    key = jax.random.PRNGKey(29)
    q, k, v = (rand(jax.random.fold_in(key, i), (g, n, d)) for i in range(3))
    q_land = jax.vmap(lambda x: ref.landmarks_pool1d(x, m))(q)

    def loss(q, k, v, q_land):
        return (ref.mita_attention_ref_b(q, k, v, q_land, kk) ** 2).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, q_land)
    for gr in grads:
        assert np.isfinite(np.array(gr)).all()
    # Gradients w.r.t. values must be nonzero (values always contribute).
    assert float(jnp.abs(grads[2]).sum()) > 0


def test_gather_rows_matches_vmap_indexing():
    g, n, d = 4, 16, 8
    x = rand(jax.random.PRNGKey(31), (g, n, d))
    idx = jax.random.randint(jax.random.PRNGKey(32), (g, 5), 0, n)
    out = ref.gather_rows(x, idx)
    expect = jax.vmap(lambda xi, ii: xi[ii])(x, idx)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=0, atol=0)
