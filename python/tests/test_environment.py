"""Environment probe — collectable on any runner, JAX or not.

Keeps `pytest python/tests` from exiting with "no tests collected" (code 5)
on machines without JAX, and makes the skip reason visible in CI logs.
"""

import importlib.util

import pytest


def _installed(module: str) -> bool:
    try:
        return importlib.util.find_spec(module) is not None
    except (ImportError, ValueError):
        return False


def test_compile_suites_runnable_or_skipped():
    if not _installed("jax"):
        pytest.skip("JAX not installed: L1/L2 compile suites ignored at collection")
    if not _installed("hypothesis"):
        pytest.skip("hypothesis not installed: kernel property sweeps ignored")
    # Both present: the real suites were collected alongside this probe.
    assert _installed("jax") and _installed("hypothesis")
