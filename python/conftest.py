"""Pytest configuration for the L1/L2 compile-time suites.

Two jobs:

1. Make ``compile`` importable when pytest is invoked from the repo root
   (``python -m pytest python/tests -q``) — the package lives next to this
   file, not on the default path.

2. Skip-if-no-JAX: the kernel/model/AOT suites import ``jax`` (and
   ``hypothesis`` for the property sweeps) at module scope, so on a plain
   runner they must be excluded at *collection* time, not at test time.
   ``test_environment.py`` stays collectable everywhere so the run reports
   an explicit skip instead of "no tests collected" (pytest exit code 5).
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def _installed(module: str) -> bool:
    try:
        return importlib.util.find_spec(module) is not None
    except (ImportError, ValueError):
        return False


HAVE_JAX = _installed("jax")
HAVE_HYPOTHESIS = _installed("hypothesis")

collect_ignore = []
if not HAVE_JAX:
    collect_ignore += [
        "tests/test_aot.py",
        "tests/test_kernels.py",
        "tests/test_model.py",
        "tests/test_perf.py",
    ]
elif not HAVE_HYPOTHESIS:
    # Only the property sweeps need hypothesis.
    collect_ignore += ["tests/test_kernels.py"]
