"""AOT pipeline: lower every experiment bundle to HLO text + manifest.json.

This is the only place Python runs — once, at build time (`make artifacts`).
The Rust coordinator consumes artifacts/{name}.hlo.txt via the PJRT C API
and artifacts/manifest.json for all shape/layout metadata.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every computation is exposed with a *flat* tensor signature so Rust can
thread plain buffer lists:

  init:        (seed i32[])                  -> P params + P mu + P nu + step
  train_step:  (P params, P mu, P nu, step, x, y)
                                             -> P params' + P mu' + P nu'
                                                + step' + loss + correct
  eval_step:   (P params, x, y)              -> loss + correct   (cls/lra)
                                             -> loss + confusion (seg)
  predict:     (P params, x)                 -> logits
  analysis:    (P params, x)                 -> logits + topk_idx + assign

P = number of parameter leaves; the flattened order (jax tree order) is
recorded per-bundle in the manifest as `param_layout`.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import re
import sys
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import ModelConfig, TrainConfig, config_to_dict
from .specs import Bundle, all_bundles

MANIFEST_VERSION = 2


# ---------------------------------------------------------------------------
# HLO text emission.
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "i32",
    jnp.dtype("uint32"): "u32",
    jnp.dtype("bfloat16"): "bf16",
}


def _tensor_spec(x) -> Dict:
    return {"shape": list(x.shape), "dtype": _DTYPE_NAMES[jnp.dtype(x.dtype)]}


# ---------------------------------------------------------------------------
# Flat-signature wrappers around model.py.
# ---------------------------------------------------------------------------


def param_template(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (no computation)."""
    return jax.eval_shape(lambda s: model.init_params(s, cfg), jnp.zeros((), jnp.int32))


def param_layout(cfg: ModelConfig) -> List[Dict]:
    tmpl = param_template(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(tmpl)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append({"path": name, **_tensor_spec(leaf)})
    return out


def _batch_specs(cfg: ModelConfig, batch: int):
    """(x_spec, y_spec) ShapeDtypeStructs for one batch."""
    if cfg.task == "lra":
        x = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
        y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    elif cfg.task == "seg_image":
        h, w = cfg.image_hw
        x = jax.ShapeDtypeStruct((batch, h, w, cfg.channels), jnp.float32)
        y = jax.ShapeDtypeStruct((batch, cfg.num_tokens), jnp.int32)
    else:
        h, w = cfg.image_hw
        x = jax.ShapeDtypeStruct((batch, h, w, cfg.channels), jnp.float32)
        y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return x, y


def build_fn(bundle: Bundle, which: str):
    """Return (flat_fn, example_args) for one computation of a bundle."""
    cfg, tcfg = bundle.model, bundle.train
    tmpl = param_template(cfg)
    flat_t, tdef = jax.tree_util.tree_flatten(tmpl)
    p_n = len(flat_t)
    x_spec, y_spec = _batch_specs(cfg, tcfg.batch_size)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)

    if which == "init":

        def fn(seed):
            params = model.init_params(seed, cfg)
            opt = model.init_opt_state(params)
            return tuple(
                jax.tree_util.tree_leaves(params)
                + jax.tree_util.tree_leaves(opt["mu"])
                + jax.tree_util.tree_leaves(opt["nu"])
                + [opt["step"]]
            )

        return fn, [jax.ShapeDtypeStruct((), jnp.int32)]

    if which == "train_step":
        step_impl = model.train_step_seg if cfg.task == "seg_image" else model.train_step

        def fn(*flat):
            params = jax.tree_util.tree_unflatten(tdef, flat[:p_n])
            mu = jax.tree_util.tree_unflatten(tdef, flat[p_n : 2 * p_n])
            nu = jax.tree_util.tree_unflatten(tdef, flat[2 * p_n : 3 * p_n])
            step = flat[3 * p_n]
            x, y = flat[3 * p_n + 1], flat[3 * p_n + 2]
            opt = {"mu": mu, "nu": nu, "step": step}
            params2, opt2, loss, correct = step_impl(params, opt, x, y, cfg, tcfg)
            return tuple(
                jax.tree_util.tree_leaves(params2)
                + jax.tree_util.tree_leaves(opt2["mu"])
                + jax.tree_util.tree_leaves(opt2["nu"])
                + [opt2["step"], loss, jnp.asarray(correct, jnp.int32)]
            )

        args = list(flat_t) * 3 + [step_spec, x_spec, y_spec]
        return fn, args

    if which == "eval_step":
        eval_impl = model.eval_step_seg if cfg.task == "seg_image" else model.eval_step

        def fn(*flat):
            params = jax.tree_util.tree_unflatten(tdef, flat[:p_n])
            x, y = flat[p_n], flat[p_n + 1]
            loss, second = eval_impl(params, x, y, cfg)
            if cfg.task == "seg_image":
                return (loss, second)  # confusion f32[C, C]
            return (loss, jnp.asarray(second, jnp.int32))

        return fn, list(flat_t) + [x_spec, y_spec]

    if which == "predict":

        def fn(*flat):
            params = jax.tree_util.tree_unflatten(tdef, flat[:p_n])
            return (model.forward(params, flat[p_n], cfg),)

        return fn, list(flat_t) + [x_spec]

    if which == "analysis":
        x_one = jax.ShapeDtypeStruct(x_spec.shape[1:], x_spec.dtype)

        def fn(*flat):
            params = jax.tree_util.tree_unflatten(tdef, flat[:p_n])
            logits, idx, assign = model.analysis_forward(params, flat[p_n], cfg)
            return (logits, idx, assign)

        return fn, list(flat_t) + [x_one]

    raise ValueError(f"unknown computation {which!r}")


# ---------------------------------------------------------------------------
# Emission + manifest.
# ---------------------------------------------------------------------------


def spec_hash(bundle: Bundle, which: str) -> str:
    blob = json.dumps(
        {
            "model": config_to_dict(bundle.model),
            "train": config_to_dict(bundle.train),
            "which": which,
            "jax": jax.__version__,
            "v": MANIFEST_VERSION,
        },
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def emit_bundle(bundle: Bundle, out_dir: Path, manifest: Dict, force: bool = False) -> int:
    """Lower all computations of a bundle; returns number actually lowered."""
    lowered_count = 0
    arts = manifest.setdefault("artifacts", {})
    bundles = manifest.setdefault("bundles", {})

    bentry = {
        "model": config_to_dict(bundle.model),
        "train": config_to_dict(bundle.train),
        "meta": bundle.meta,
        "param_layout": param_layout(bundle.model),
        "artifacts": {},
    }

    for which in bundle.emit:
        name = f"{bundle.name}.{which}"
        fname = f"{name}.hlo.txt"
        h = spec_hash(bundle, which)
        prev = arts.get(name)
        bentry["artifacts"][which] = name
        if not force and prev and prev.get("spec_hash") == h and (out_dir / fname).exists():
            continue

        t0 = time.time()
        fn, args = build_fn(bundle, which)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        (out_dir / fname).write_text(text)

        out_shapes = jax.eval_shape(fn, *args)
        arts[name] = {
            "file": fname,
            "spec_hash": h,
            "inputs": [_tensor_spec(a) for a in args],
            "outputs": [_tensor_spec(o) for o in out_shapes],
        }
        lowered_count += 1
        print(f"  lowered {name}  ({time.time() - t0:.1f}s, {len(text) / 1e6:.2f} MB)")

    bundles[bundle.name] = bentry
    return lowered_count


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="regex filter on bundle names")
    ap.add_argument("--list", action="store_true", help="list bundles and exit")
    ap.add_argument("--force", action="store_true", help="re-lower even if cached")
    args = ap.parse_args(argv)

    bundles = all_bundles()
    if args.only:
        rx = re.compile(args.only)
        bundles = [b for b in bundles if rx.search(b.name)]

    if args.list:
        for b in bundles:
            print(f"{b.name:28s} {b.model.task:10s} emit={','.join(b.emit)}")
        print(f"{len(bundles)} bundles")
        return

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    manifest = {"version": MANIFEST_VERSION}
    if manifest_path.exists():
        try:
            old = json.loads(manifest_path.read_text())
            if old.get("version") == MANIFEST_VERSION:
                manifest = old
        except json.JSONDecodeError:
            pass

    total = 0
    t0 = time.time()
    for i, b in enumerate(bundles):
        print(f"[{i + 1}/{len(bundles)}] {b.name}")
        total += emit_bundle(b, out_dir, manifest, force=args.force)
        # Persist incrementally so an interrupted run resumes cleanly.
        manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"done: {total} computations lowered in {time.time() - t0:.0f}s -> {out_dir}")


if __name__ == "__main__":
    main()
