"""L2 — JAX model zoo: ViT classifier / segmenter and LRA sequence nets.

Pure-functional (params are nested dicts of jnp arrays; no flax/optax — the
image ships neither). Every computation that Rust needs is expressed as a
jittable function of flat tensors:

  * ``init_params(seed)``                       — parameter initialization
  * ``forward(params, x)``                      — logits
  * ``train_step(params, opt, x, y)``           — AdamW update + metrics
  * ``eval_step(params, x, y)``                 — loss / correct / confusion

Attention is pluggable via AttentionConfig.kind; all kinds share identical
parameter shapes (the swap experiments of Fig. 9 / Tab. 7 rely on this),
except landmark mode "learned" which adds a `landmarks` parameter.

Training artifacts call the differentiable reference math
(kernels.ref / kernels.mita with use_pallas=False); inference artifacts may
route through the Pallas kernel (use_pallas=True).
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .configs import AttentionConfig, ModelConfig, TrainConfig
from .kernels import attention as attn_kernel
from .kernels import mita as mita_kernel
from .kernels import ref


# ---------------------------------------------------------------------------
# Parameter initialization.
# ---------------------------------------------------------------------------


def _init_linear(key, din: int, dout: int, scale: float | None = None) -> Dict:
    scale = scale if scale is not None else (2.0 / (din + dout)) ** 0.5
    return {
        "w": jax.random.normal(key, (din, dout), jnp.float32) * scale,
        "b": jnp.zeros((dout,), jnp.float32),
    }


def _init_layernorm(dim: int) -> Dict:
    return {"g": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


def _init_block(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 8)
    dim = cfg.dim
    hidden = int(dim * cfg.mlp_ratio)
    p = {
        "ln1": _init_layernorm(dim),
        "qkv": _init_linear(ks[0], dim, 3 * dim),
        "proj": _init_linear(ks[1], dim, dim),
        "ln2": _init_layernorm(dim),
        "fc1": _init_linear(ks[2], dim, hidden),
        "fc2": _init_linear(ks[3], hidden, dim),
    }
    if cfg.attention.landmark == "learned":
        p["landmarks"] = jax.random.normal(ks[4], (cfg.attention.m, dim), jnp.float32) * 0.02
    if cfg.dwc:
        # Depth-wise 3x3 (image) / 3 (sequence) conv over values.
        if cfg.task == "lra":
            p["dwc"] = jax.random.normal(ks[5], (3, dim), jnp.float32) * 0.1
        else:
            p["dwc"] = jax.random.normal(ks[5], (3, 3, dim), jnp.float32) * 0.1
    if cfg.gate:
        p["gate"] = _init_linear(ks[6], dim, dim, scale=0.02)
    return p


def init_params(seed: jax.Array, cfg: ModelConfig) -> Dict:
    """Initialize all model parameters from an int32 seed scalar (jittable)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, cfg.depth + 4)
    n = cfg.num_tokens
    dim = cfg.dim
    params: Dict = {
        "blocks": {f"{i:02d}": _init_block(ks[i], cfg) for i in range(cfg.depth)},
        "ln_f": _init_layernorm(dim),
        "pos": jax.random.normal(ks[cfg.depth], (n, dim), jnp.float32) * 0.02,
        "head": _init_linear(ks[cfg.depth + 1], dim, cfg.num_classes),
    }
    if cfg.task == "lra":
        params["embed"] = jax.random.normal(ks[cfg.depth + 2], (cfg.vocab, dim), jnp.float32) * 0.02
    else:
        pdim = cfg.patch * cfg.patch * cfg.channels
        params["patch"] = _init_linear(ks[cfg.depth + 2], pdim, dim)
    return params


# ---------------------------------------------------------------------------
# Forward pieces.
# ---------------------------------------------------------------------------


def _layernorm(p: Dict, x: jax.Array) -> jax.Array:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * p["g"] + p["b"]


def _linear(p: Dict, x: jax.Array) -> jax.Array:
    return x @ p["w"] + p["b"]


def _dwc(p: Dict, v: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Depth-wise conv over values on the token grid (Tab. 2 DWC variant).

    v: [B, N, dim] -> [B, N, dim].
    """
    dim = cfg.dim
    b = v.shape[0]
    if cfg.task == "lra":
        out = jax.lax.conv_general_dilated(
            v,
            p["dwc"][:, None, :],  # [3, 1, dim]
            window_strides=(1,),
            padding="SAME",
            dimension_numbers=("NHC", "HIO", "NHC"),
            feature_group_count=dim,
        )
        return out
    gh, gw = cfg.grid_hw
    x = v.reshape(b, gh, gw, dim)
    out = jax.lax.conv_general_dilated(
        x,
        p["dwc"][:, :, None, :],  # [3, 3, 1, dim]
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=dim,
    )
    return out.reshape(b, gh * gw, dim)


def _split_heads_b(x: jax.Array, heads: int) -> jax.Array:
    """[B, N, D] -> [B*H, N, D/H] (the G-flat layout; see kernels/ref.py)."""
    b, n, dd = x.shape
    return x.reshape(b, n, heads, dd // heads).transpose(0, 2, 1, 3).reshape(b * heads, n, dd // heads)


def _merge_heads_b(x: jax.Array, batch: int) -> jax.Array:
    """[B*H, N, d] -> [B, N, H*d]."""
    g, n, d = x.shape
    heads = g // batch
    return x.reshape(batch, heads, n, d).transpose(0, 2, 1, 3).reshape(batch, n, heads * d)


def _head_landmarks_b(q_heads: jax.Array, p: Dict, cfg: ModelConfig, batch: int) -> jax.Array:
    """Landmark queries per (batch, head): q_heads [G, N, d] -> [G, m, d].

    Pooling strategies are expressed as constant matrices applied by einsum
    (no gathers — the AOT interchange cannot convert batched gathers).
    """
    acfg = cfg.attention
    heads = cfg.heads
    g, n, d = q_heads.shape

    if acfg.landmark == "learned":
        per_head = ref.split_heads(p["landmarks"], heads)  # [H, m, d]
        return jnp.tile(per_head, (batch, 1, 1))

    if acfg.landmark == "pool2d" and cfg.task != "lra":
        gh, gw = cfg.grid_hw
        mh = int(acfg.m**0.5)
        while acfg.m % mh != 0:
            mh -= 1
        mw = acfg.m // mh
        ph = ref._adaptive_pool_matrix(gh, mh, q_heads.dtype)  # [mh, gh]
        pw = ref._adaptive_pool_matrix(gw, mw, q_heads.dtype)  # [mw, gw]
        x = q_heads.reshape(g, gh, gw, d)
        x = jnp.einsum("ih,ghwd->giwd", ph, x)
        x = jnp.einsum("jw,giwd->gijd", pw, x)
        return x.reshape(g, mh * mw, d)

    if acfg.landmark == "random":
        # Fixed-seed random selection expressed as a constant 0/1 matrix.
        import numpy as np

        rng = np.random.default_rng(0)
        sel_idx = np.sort(rng.permutation(n)[: acfg.m])
        sel = np.zeros((acfg.m, n), dtype=np.float32)
        sel[np.arange(acfg.m), sel_idx] = 1.0
        return jnp.einsum("mn,gnd->gmd", jnp.asarray(sel, q_heads.dtype), q_heads)

    # pool1d (also the fallback for pool2d on 1-D tasks).
    pm = ref._adaptive_pool_matrix(n, acfg.m, q_heads.dtype)  # [m, n]
    return jnp.einsum("mn,gnd->gmd", pm, q_heads)


def _attention(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Token-mixing layer. x: [B, N, dim] -> [B, N, dim]."""
    acfg = cfg.attention
    heads = cfg.heads
    b = x.shape[0]
    qkv = _linear(p["qkv"], x)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qs, ks, vs = (_split_heads_b(t, heads) for t in (q, k, v))  # [G, N, d]

    kind = acfg.kind
    if kind == "standard":
        if acfg.use_pallas:
            out = attn_kernel.flash_attention_b(qs, ks, vs)
        else:
            out = ref.softmax_attention_b(qs, ks, vs)
    elif kind == "linear":
        out = ref.linear_attention_b(qs, ks, vs)
    else:
        lands = _head_landmarks_b(qs, p, cfg, b)  # [G, m, d]
        if kind == "agent":
            out = ref.agent_attention_b(qs, ks, vs, lands)
        else:
            include_shared = kind in ("mita", "mita_compress")
            include_routed = kind in ("mita", "mita_route")
            out = mita_kernel.mita_attention_b(
                qs,
                ks,
                vs,
                lands,
                kk=acfg.k,
                s=acfg.s,
                use_pallas=acfg.use_pallas,
                include_shared=include_shared,
                include_routed=include_routed,
                cap_factor=acfg.cap_factor,
            )

    out = _merge_heads_b(out, b)
    if cfg.dwc:
        out = out + _dwc(p, v, cfg)
    out = _linear(p["proj"], out)
    if cfg.gate:
        out = out * jax.nn.sigmoid(_linear(p["gate"], x))
    return out


def _mlp(p: Dict, x: jax.Array) -> jax.Array:
    return _linear(p["fc2"], jax.nn.gelu(_linear(p["fc1"], x)))


def _block(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = x + _attention(p, _layernorm(p["ln1"], x), cfg)
    x = x + _mlp(p, _layernorm(p["ln2"], x))
    return x


def _patchify(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[B, H, W, C] images -> [B, N, patch*patch*C] flattened patches."""
    b = x.shape[0]
    h, w = cfg.image_hw
    pp = cfg.patch
    c = cfg.channels
    x = x.reshape(b, h // pp, pp, w // pp, pp, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, (h // pp) * (w // pp), pp * pp * c)


def _encode(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Batched encoder: input -> [B, N, dim] token features."""
    if cfg.task == "lra":
        tok = params["embed"][x]  # [B, N, dim] (unbatched-operand gather)
    else:
        tok = _linear(params["patch"], _patchify(x, cfg))
    tok = tok + params["pos"]
    for i in range(cfg.depth):
        tok = _block(params["blocks"][f"{i:02d}"], tok, cfg)
    return _layernorm(params["ln_f"], tok)


def forward(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Batched forward. x: [B, ...] -> logits.

    cls_image / lra -> [B, num_classes]; seg_image -> [B, N, num_classes].
    """
    tok = _encode(params, x, cfg)
    if cfg.task == "seg_image":
        return _linear(params["head"], tok)  # per-token logits
    pooled = tok.mean(axis=1) if cfg.pool == "mean" else tok[:, 0]
    return _linear(params["head"], pooled)


# ---------------------------------------------------------------------------
# Loss / metrics.
# ---------------------------------------------------------------------------


def _xent(logits: jax.Array, y: jax.Array, num_classes: int, smoothing: float) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, num_classes, dtype=logits.dtype)
    if smoothing > 0:
        onehot = onehot * (1 - smoothing) + smoothing / num_classes
    return -(onehot * logp).sum(-1)


def loss_fn(params: Dict, x: jax.Array, y: jax.Array, cfg: ModelConfig, smoothing: float = 0.0):
    logits = forward(params, x, cfg)
    loss = _xent(logits, y, cfg.num_classes, smoothing).mean()
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == y).sum()
    return loss, correct


# ---------------------------------------------------------------------------
# AdamW (hand-rolled; no optax in the image).
# ---------------------------------------------------------------------------


def init_opt_state(params: Dict) -> Dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}


def _lr_schedule(step: jax.Array, tcfg: TrainConfig) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(tcfg.warmup_steps, 1))
    prog = jnp.clip((step - tcfg.warmup_steps) / max(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tcfg.lr * warm * cos


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: (g.astype(jnp.float32) ** 2).sum(), tree))
    return jnp.sqrt(jnp.asarray(leaves).sum())


def train_step(
    params: Dict,
    opt: Dict,
    x: jax.Array,
    y: jax.Array,
    cfg: ModelConfig,
    tcfg: TrainConfig,
):
    """One AdamW step. Returns (params', opt', loss, correct)."""
    (loss, correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, x, y, cfg, tcfg.label_smoothing
    )

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-6))
    grads = jax.tree.map(lambda g: g * clip, grads)

    step = opt["step"]
    lr = _lr_schedule(step, tcfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - tcfg.beta1**t
    bc2 = 1 - tcfg.beta2**t

    def upd(p, g, mu, nu):
        mu = tcfg.beta1 * mu + (1 - tcfg.beta1) * g
        nu = tcfg.beta2 * nu + (1 - tcfg.beta2) * (g * g)
        mhat = mu / bc1
        nhat = nu / bc2
        newp = p - lr * (mhat / (jnp.sqrt(nhat) + tcfg.eps) + tcfg.weight_decay * p)
        return newp, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt["mu"])
    flat_nu = jax.tree.leaves(opt["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)

    params2 = jax.tree.unflatten(tdef, new_p)
    opt2 = {
        "mu": jax.tree.unflatten(tdef, new_mu),
        "nu": jax.tree.unflatten(tdef, new_nu),
        "step": step + 1,
    }
    return params2, opt2, loss, correct


# ---------------------------------------------------------------------------
# Eval steps.
# ---------------------------------------------------------------------------


def eval_step(params: Dict, x: jax.Array, y: jax.Array, cfg: ModelConfig):
    """Classification eval: (loss_sum, correct) over the batch."""
    logits = forward(params, x, cfg)
    loss = _xent(logits, y, cfg.num_classes, 0.0).sum()
    correct = (jnp.argmax(logits, -1) == y).sum()
    return loss, correct


def eval_step_seg(params: Dict, x: jax.Array, y: jax.Array, cfg: ModelConfig):
    """Segmentation eval: per-batch confusion matrix [C, C] (rows = truth).

    Rust accumulates confusions across batches and derives mIoU — the
    Tab. 4 metric.
    """
    logits = forward(params, x, cfg)  # [B, N, C]
    pred = jnp.argmax(logits, -1).reshape(-1)
    truth = y.reshape(-1)
    c = cfg.num_classes
    onehot_t = jax.nn.one_hot(truth, c, dtype=jnp.float32)
    onehot_p = jax.nn.one_hot(pred, c, dtype=jnp.float32)
    confusion = onehot_t.T @ onehot_p
    loss = _xent(logits.reshape(-1, c), truth, c, 0.0).mean()
    return loss, confusion


def seg_loss_fn(params: Dict, x: jax.Array, y: jax.Array, cfg: ModelConfig, smoothing: float = 0.0):
    logits = forward(params, x, cfg)  # [B, N, C]
    c = cfg.num_classes
    loss = _xent(logits.reshape(-1, c), y.reshape(-1), c, smoothing).mean()
    correct = (jnp.argmax(logits, -1) == y).sum()
    return loss, correct


def train_step_seg(params, opt, x, y, cfg: ModelConfig, tcfg: TrainConfig):
    """Segmentation train step (per-token CE)."""
    (loss, correct), grads = jax.value_and_grad(seg_loss_fn, has_aux=True)(
        params, x, y, cfg, tcfg.label_smoothing
    )
    # Re-use the classification updater by faking the loss closure: identical
    # AdamW math, so we inline the same update here.
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-6))
    grads = jax.tree.map(lambda g: g * clip, grads)
    step = opt["step"]
    lr = _lr_schedule(step, tcfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - tcfg.beta1**t
    bc2 = 1 - tcfg.beta2**t

    def upd(p, g, mu, nu):
        mu = tcfg.beta1 * mu + (1 - tcfg.beta1) * g
        nu = tcfg.beta2 * nu + (1 - tcfg.beta2) * (g * g)
        newp = p - lr * ((mu / bc1) / (jnp.sqrt(nu / bc2) + tcfg.eps) + tcfg.weight_decay * p)
        return newp, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    triples = [
        upd(p, g, mu, nu)
        for p, g, mu, nu in zip(
            flat_p, jax.tree.leaves(grads), jax.tree.leaves(opt["mu"]), jax.tree.leaves(opt["nu"])
        )
    ]
    params2 = jax.tree.unflatten(tdef, [a for a, _, _ in triples])
    opt2 = {
        "mu": jax.tree.unflatten(tdef, [b for _, b, _ in triples]),
        "nu": jax.tree.unflatten(tdef, [c for _, _, c in triples]),
        "step": step + 1,
    }
    return params2, opt2, loss, correct


# ---------------------------------------------------------------------------
# Analysis forward (Figs. 3/4/8): expose routing internals of every layer.
# ---------------------------------------------------------------------------


def analysis_forward(params: Dict, x: jax.Array, cfg: ModelConfig):
    """Forward of one example returning per-layer MiTA internals.

    x is a single unbatched example. Returns (logits, topk_idx
    [depth, H, m, k] i32, assign [depth, H, N] i32) — everything Rust needs
    to render Fig. 3/4 heatmaps and the Fig. 8 overlap metric.
    """
    acfg = cfg.attention
    assert acfg.kind.startswith("mita")
    heads = cfg.heads

    xb = x[None]  # batch of 1
    if cfg.task == "lra":
        tok = params["embed"][xb]
    else:
        tok = _linear(params["patch"], _patchify(xb, cfg))
    tok = tok + params["pos"]

    idx_layers, assign_layers = [], []
    for i in range(cfg.depth):
        p = params["blocks"][f"{i:02d}"]
        xin = _layernorm(p["ln1"], tok)
        qkv = _linear(p["qkv"], xin)
        q, k, _ = jnp.split(qkv, 3, axis=-1)
        qs = _split_heads_b(q, heads)  # [H, N, d] (batch of 1)
        ks_ = _split_heads_b(k, heads)
        lands = _head_landmarks_b(qs, p, cfg, 1)  # [H, m, d]

        scores = ref.mita_scores_b(ks_, lands)  # [H, N, m]
        idx = ref.mita_topk_indices_b(scores, acfg.k)  # [H, m, k]
        e = ref.mita_routing_b(qs, lands, 1)[..., 0]  # [H, N]
        idx_layers.append(idx.astype(jnp.int32))
        assign_layers.append(e.astype(jnp.int32))
        tok = _block(p, tok, cfg)

    tok = _layernorm(params["ln_f"], tok)
    pooled = tok.mean(axis=1) if cfg.pool == "mean" else tok[:, 0]
    logits = _linear(params["head"], pooled)[0]
    return logits, jnp.stack(idx_layers), jnp.stack(assign_layers)
