"""Pallas tiled softmax attention (FlashAttention-style baseline kernel).

Grid is (q_blocks, k_blocks); the k axis is the sequential minor axis and
partial results are carried across k blocks in VMEM scratch using the
online-softmax recurrence (Milakov & Gimelshein, 2018). This is the TPU
re-think of the paper's FlashAttention baseline: HBM→VMEM streaming is
expressed via BlockSpec instead of threadblock SRAM tiles, and the inner
matmuls target the MXU.

All kernels run with interpret=True — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; numerics are validated
through the interpret path against kernels.ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, kblocks):
    """One (q_block, k_block) grid step of the online-softmax recurrence."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # [bq, d]
    k = k_ref[...]  # [bk, d]
    v = v_ref[...]  # [bk, d]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new

    @pl.when(j == kblocks - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Tiled softmax attention for one head. q,k,v: [N, d] -> [N, d].

    N must be divisible by the block sizes (callers pad; the model layer
    always uses power-of-two friendly shapes).
    """
    n, d = q.shape
    nk = k.shape[0]
    block_q = min(block_q, n)
    block_k = min(block_k, nk)
    assert n % block_q == 0 and nk % block_k == 0, (n, nk, block_q, block_k)
    qblocks, kblocks = n // block_q, nk // block_k
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, kblocks=kblocks)
    return pl.pallas_call(
        kernel,
        grid=(qblocks, kblocks),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)


def flash_attention_mh(q: jax.Array, k: jax.Array, v: jax.Array, heads: int, **kw) -> jax.Array:
    """Multi-head wrapper: q,k,v [N, D] with D = heads * d."""
    from . import ref

    qs, ks, vs = (ref.split_heads(x, heads) for x in (q, k, v))
    out = jax.vmap(lambda a, b, c: flash_attention(a, b, c, **kw))(qs, ks, vs)
    return ref.merge_heads(out)


def _flash_kernel_b(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, kblocks):
    """Batched-grid flash step: blocks carry a leading singleton G axis."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new

    @pl.when(j == kblocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_b(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Batched tiled softmax attention: q,k,v [G, N, d] -> [G, N, d].

    The batch/head axis G is a grid dimension (no vmap — see kernels/ref.py
    on why the AOT path avoids vmapped memory ops).
    """
    g, n, d = q.shape
    nk = k.shape[1]
    block_q = min(block_q, n)
    block_k = min(block_k, nk)
    assert n % block_q == 0 and nk % block_k == 0, (n, nk, block_q, block_k)
    qblocks, kblocks = n // block_q, nk // block_k
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(_flash_kernel_b, scale=scale, kblocks=kblocks)
    return pl.pallas_call(
        kernel,
        grid=(g, qblocks, kblocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda gi, i, j: (gi, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda gi, i, j: (gi, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda gi, i, j: (gi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda gi, i, j: (gi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
