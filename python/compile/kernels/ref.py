"""Pure-jnp reference oracles for every attention mechanism in the repo.

These are the *exact* math of the paper, written with plain gathers and a
single softmax — no capacity limits, no tiling, no Pallas. They serve three
roles:

  1. correctness oracle for the Pallas kernels (pytest + hypothesis),
  2. the differentiable path used inside AOT-compiled train steps
     (Pallas interpret-mode has no autodiff rule),
  3. the semantics the Rust-side `mita` analysis module mirrors (routing,
     top-k sets, overlap metrics for Figs. 3/4/8).

All single-head functions take row-major `[N, d]` arrays; multi-head wrappers
vmap over a leading `[H]` axis and batch wrappers over `[B, H]`.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Full (standard) softmax attention — Eq. (1); the N-width fast-weight MLP.
# ---------------------------------------------------------------------------


def softmax_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Standard scaled-dot-product attention. q,k,v: [N, d] -> [N, d]."""
    d = q.shape[-1]
    logits = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    return jax.nn.softmax(logits, axis=-1) @ v


# ---------------------------------------------------------------------------
# Linear attention (Katharopoulos et al., 2020) — scaling by compression
# into a single fast-weight linear layer.
# ---------------------------------------------------------------------------


def _elu1(x: jax.Array) -> jax.Array:
    return jax.nn.elu(x) + 1.0


def linear_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Kernelized linear attention with the elu+1 feature map. [N,d]->[N,d]."""
    qf, kf = _elu1(q), _elu1(k)
    kv = kf.T @ v  # [d, d] — the compressed fast weights
    den = qf @ kf.sum(axis=0)  # [N]
    return (qf @ kv) / (den[:, None] + 1e-6)


# ---------------------------------------------------------------------------
# Landmark extraction (Sec. 3.2 + Tab. 6 ablation).
# ---------------------------------------------------------------------------


def _adaptive_pool_matrix(n: int, m: int, dtype=jnp.float32) -> jax.Array:
    """[m, n] averaging matrix of AdaptiveAvgPool1d(n -> m).

    Element r belongs to window i iff floor(i*n/m) <= r < floor((i+1)*n/m)
    — PyTorch's adaptive pooling windows (Alg. 1 line 2 uses
    AdaptiveAvgPool). Built at trace time from static shapes.
    """
    assert 1 <= m <= n, (n, m)
    r = jnp.arange(n)
    lo = (jnp.arange(m) * n) // m
    hi = ((jnp.arange(m) + 1) * n) // m
    mask = (r[None, :] >= lo[:, None]) & (r[None, :] < hi[:, None])
    mat = mask.astype(dtype)
    return mat / mat.sum(axis=1, keepdims=True)


def landmarks_pool2d(q: jax.Array, grid_hw: Tuple[int, int], m_hw: Tuple[int, int]) -> jax.Array:
    """2-D adaptive average pooling of queries over the token grid.

    q: [N, d] with N = H*W laid out row-major over the token grid.
    Returns [m, d] with m = mh*mw (windows need not divide the grid —
    adaptive windows as in AdaptiveAvgPool2d, e.g. N=196=14², m=25=5²).
    """
    h, w = grid_hw
    mh, mw = m_hw
    d = q.shape[-1]
    ph = _adaptive_pool_matrix(h, mh, q.dtype)  # [mh, h]
    pw = _adaptive_pool_matrix(w, mw, q.dtype)  # [mw, w]
    x = q.reshape(h, w, d)
    x = jnp.einsum("ih,hwd->iwd", ph, x)
    x = jnp.einsum("jw,iwd->ijd", pw, x)
    return x.reshape(mh * mw, d)


def landmarks_pool1d(q: jax.Array, m: int) -> jax.Array:
    """1-D adaptive average pooling. q: [N, d] -> [m, d]."""
    p = _adaptive_pool_matrix(q.shape[0], m, q.dtype)
    return p @ q


def landmarks_random(q: jax.Array, m: int, seed: int = 0) -> jax.Array:
    """Random (but fixed-seed, hence deterministic) query selection."""
    n = q.shape[0]
    idx = jax.random.permutation(jax.random.PRNGKey(seed), n)[:m]
    return q[jnp.sort(idx)]


def extract_landmarks(
    q: jax.Array,
    mode: str,
    m: int,
    grid_hw: Optional[Tuple[int, int]] = None,
    learned: Optional[jax.Array] = None,
) -> jax.Array:
    """Dispatch over the Tab. 6 landmark-extraction strategies."""
    if mode == "pool2d":
        assert grid_hw is not None
        # Factor m into the most-square mh x mw window grid (mh <= mw).
        mh = int(m**0.5)
        while m % mh != 0:
            mh -= 1
        return landmarks_pool2d(q, grid_hw, (mh, m // mh))
    if mode == "pool1d":
        return landmarks_pool1d(q, m)
    if mode == "random":
        return landmarks_random(q, m)
    if mode == "learned":
        assert learned is not None and learned.shape[0] == m
        return learned.astype(q.dtype)
    raise ValueError(f"unknown landmark mode {mode!r}")


# ---------------------------------------------------------------------------
# MiTA internals — Eqs. (5)–(12) / Algorithm 1, exact (no capacity).
# ---------------------------------------------------------------------------


def mita_scores(k: jax.Array, q_land: jax.Array) -> jax.Array:
    """Landmark scores S = K^T Q̃ / sqrt(d): [N, m] (Alg. 1 line 4)."""
    d = k.shape[-1]
    return (k @ q_land.T) / jnp.sqrt(jnp.asarray(d, k.dtype))


def _topk_idx(x: jax.Array, kk: int) -> jax.Array:
    """Indices of the k largest entries per row of x: [..., n] -> [..., k].

    Implemented with argsort (lowers to the HLO `sort` op) instead of
    jax.lax.top_k: jax >= 0.5 lowers top_k to the dedicated `topk` HLO
    instruction whose text form (`largest=true`) the pinned xla_extension
    0.5.1 parser rejects. Sort keeps the AOT interchange parseable.

    The sort input is stop_gradient'ed: index selection is discrete (no
    useful gradient), and sort's JVP permutes tangents with a batched
    gather that the pinned interchange cannot express. Gradients still
    flow through the gathered keys/values, as in MoBA/NSA.
    """
    return jnp.argsort(jax.lax.stop_gradient(-x), axis=-1)[..., :kk]


def mita_topk_indices(scores: jax.Array, kk: int) -> jax.Array:
    """Top-k key/value indices per expert (Eq. 7). scores: [N, m] -> [m, k]."""
    return _topk_idx(scores.T, kk)  # [m, k]


def mita_landmark_values(scores: jax.Array, v: jax.Array) -> jax.Array:
    """Landmark values Ṽ via cross-attention (Eq. 8): ṽ_i = Atten(q̃_i, K, V).

    scores: [N, m] (already scaled), v: [N, d] -> [m, d].
    """
    attn = jax.nn.softmax(scores, axis=0)  # softmax over N per landmark
    return attn.T @ v


def mita_routing(q: jax.Array, q_land: jax.Array, s: int = 1) -> jax.Array:
    """Route each query to its top-s experts by logits Q^T Q̃: [N, s]."""
    logits = q @ q_land.T  # [N, m]
    if s == 1:
        return jnp.argmax(logits, axis=-1)[:, None]
    return _topk_idx(logits, s)


def mita_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_land: jax.Array,
    kk: int,
    s: int = 1,
    include_shared: bool = True,
    include_routed: bool = True,
) -> jax.Array:
    """Exact MiTA (Eq. 10): one softmax over [Q̃ | K^(e_1(q)) | ... ] per query.

    q,k,v: [N, d]; q_land: [m, d]. Returns [N, d].

    include_shared/include_routed select the compress-only / route-only
    ablations of Tab. 6 (at least one must be set).
    """
    assert include_shared or include_routed
    n, d = q.shape
    m = q_land.shape[0]
    scale = jnp.sqrt(jnp.asarray(d, q.dtype))

    scores = mita_scores(k, q_land)  # [N, m]
    parts_k, parts_v = [], []

    if include_shared:
        v_land = mita_landmark_values(scores, v)  # [m, d]
        parts_k.append(jnp.broadcast_to(q_land[None], (n, m, d)))
        parts_v.append(jnp.broadcast_to(v_land[None], (n, m, d)))

    if include_routed:
        idx = mita_topk_indices(scores, kk)  # [m, kk]
        ke = k[idx]  # [m, kk, d]
        ve = v[idx]
        e = mita_routing(q, q_land, s)  # [n, s]
        # Gather each query's s routed experts and flatten: [n, s*kk, d].
        parts_k.append(ke[e].reshape(n, s * kk, d))
        parts_v.append(ve[e].reshape(n, s * kk, d))

    k_star = jnp.concatenate(parts_k, axis=1)  # [n, m + s*kk, d]
    v_star = jnp.concatenate(parts_v, axis=1)
    logits = jnp.einsum("nd,npd->np", q, k_star) / scale
    attn = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("np,npd->nd", attn, v_star)


def agent_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, q_land: jax.Array
) -> jax.Array:
    """Agent Attention (Han et al., 2024): softmax(Q A^T) softmax(A K^T) V.

    Differs from MiTA compress-only in that *both* softmaxes are standard
    row softmaxes (agent tokens aggregate, then broadcast). [N,d]->[N,d].
    """
    d = q.shape[-1]
    scale = jnp.sqrt(jnp.asarray(d, q.dtype))
    agg = jax.nn.softmax((q_land @ k.T) / scale, axis=-1) @ v  # [m, d]
    return jax.nn.softmax((q @ q_land.T) / scale, axis=-1) @ agg


# ---------------------------------------------------------------------------
# Online-softmax combine (Alg. 1 line 16) — reference used by kernel tests.
# ---------------------------------------------------------------------------


def online_softmax_combine(
    o1: jax.Array, m1: jax.Array, l1: jax.Array, o2: jax.Array, m2: jax.Array, l2: jax.Array
) -> jax.Array:
    """Combine two partial attention results (outputs, row maxima, row sums).

    Each (o, m, l) is an *unnormalized* partial softmax-attention over a
    disjoint key set: o = sum_j exp(s_j - m) v_j, l = sum_j exp(s_j - m),
    m = max_j s_j. Returns the exact attention output over the union.
    """
    mx = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - mx)[..., None]
    a2 = jnp.exp(m2 - mx)[..., None]
    num = o1 * a1 + o2 * a2
    den = l1 * jnp.exp(m1 - mx) + l2 * jnp.exp(m2 - mx)
    return num / den[..., None]


def partial_softmax(q: jax.Array, k: jax.Array, v: jax.Array):
    """Unnormalized partial attention over one key set (for combine tests)."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    mx = s.max(axis=-1)
    p = jnp.exp(s - mx[:, None])
    return p @ v, mx, p.sum(axis=-1)


# ---------------------------------------------------------------------------
# Batched ("G-flat") implementations.
#
# The AOT interchange (xla_extension 0.5.1) cannot convert gathers/scatters
# with `operand_batching_dims`, which is exactly what jax.vmap produces for
# fancy indexing. The model therefore never vmaps over gather-bearing code:
# batch and heads are merged into one leading axis G = B*H and every gather
# is a *flat* row gather on a reshaped [G*N, d] operand (plain gather, no
# batching dims). The single-head functions above remain the test oracles.
# ---------------------------------------------------------------------------


def gather_rows(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Batch-safe row gather: x [G, N, d], idx [G, ...] -> [G, ..., d].

    Flattens to a single non-batched gather (old-HLO friendly).
    """
    g, n, d = x.shape
    offsets = jnp.arange(g, dtype=idx.dtype).reshape((g,) + (1,) * (idx.ndim - 1))
    flat = x.reshape(g * n, d)
    return flat[(idx + offsets * n).reshape(-1)].reshape(idx.shape + (d,))


def softmax_attention_b(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Batched standard attention: q,k,v [G, N, d] -> [G, N, d]."""
    d = q.shape[-1]
    logits = jnp.einsum("gnd,gpd->gnp", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    return jnp.einsum("gnp,gpd->gnd", jax.nn.softmax(logits, axis=-1), v)


def linear_attention_b(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Batched linear attention: [G, N, d] -> [G, N, d]."""
    qf, kf = _elu1(q), _elu1(k)
    kv = jnp.einsum("gnd,gne->gde", kf, v)
    den = jnp.einsum("gnd,gd->gn", qf, kf.sum(axis=1))
    return jnp.einsum("gnd,gde->gne", qf, kv) / (den[..., None] + 1e-6)


def agent_attention_b(
    q: jax.Array, k: jax.Array, v: jax.Array, q_land: jax.Array
) -> jax.Array:
    """Batched Agent Attention: q,k,v [G,N,d], q_land [G,m,d] -> [G,N,d]."""
    d = q.shape[-1]
    scale = jnp.sqrt(jnp.asarray(d, q.dtype))
    s1 = jnp.einsum("gmd,gnd->gmn", q_land, k) / scale
    agg = jnp.einsum("gmn,gnd->gmd", jax.nn.softmax(s1, axis=-1), v)
    s2 = jnp.einsum("gnd,gmd->gnm", q, q_land) / scale
    return jnp.einsum("gnm,gmd->gnd", jax.nn.softmax(s2, axis=-1), agg)


def mita_scores_b(k: jax.Array, q_land: jax.Array) -> jax.Array:
    """Batched landmark scores: [G, N, m]."""
    d = k.shape[-1]
    return jnp.einsum("gnd,gmd->gnm", k, q_land) / jnp.sqrt(jnp.asarray(d, k.dtype))


def mita_landmark_values_b(scores: jax.Array, v: jax.Array) -> jax.Array:
    """Batched landmark values Ṽ: scores [G,N,m], v [G,N,d] -> [G,m,d]."""
    attn = jax.nn.softmax(scores, axis=1)  # softmax over N
    return jnp.einsum("gnm,gnd->gmd", attn, v)


def mita_topk_indices_b(scores: jax.Array, kk: int) -> jax.Array:
    """Batched top-k per expert: scores [G,N,m] -> [G,m,kk] (sort-based)."""
    return _topk_idx(scores.transpose(0, 2, 1), kk)


def mita_routing_b(q: jax.Array, q_land: jax.Array, s: int = 1) -> jax.Array:
    """Batched routing: [G, N, s] expert ids."""
    logits = jnp.einsum("gnd,gmd->gnm", q, q_land)
    if s == 1:
        return jnp.argmax(logits, axis=-1)[..., None]
    return _topk_idx(logits, s)


def mita_attention_ref_b(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_land: jax.Array,
    kk: int,
    s: int = 1,
    include_shared: bool = True,
    include_routed: bool = True,
) -> jax.Array:
    """Batched exact MiTA (Eq. 10): q,k,v [G,N,d], q_land [G,m,d] -> [G,N,d].

    §Perf note: the single softmax over the concatenation [Q̃ | K^(e(q))]
    is computed as two branches fused by the online-softmax combine rather
    than materializing the [G, N, m, d] broadcast of the shared expert —
    the concat form allocates 2·G·N·m·d floats per layer (1 GiB at the
    Fig. 5 N=4096 scale) for tensors whose contents are pure broadcasts.
    The combine is exact (tested against the single-head concat oracle).
    """
    assert include_shared or include_routed
    g, n, d = q.shape
    scale = jnp.sqrt(jnp.asarray(d, q.dtype))

    scores = mita_scores_b(k, q_land)  # [G, N, m]

    acc = None  # unnormalized output, row max, row sum
    if include_shared:
        v_land = mita_landmark_values_b(scores, v)  # [G, m, d]
        s_sh = jnp.einsum("gnd,gmd->gnm", q, q_land) / scale
        m1 = s_sh.max(axis=-1)
        p1 = jnp.exp(s_sh - m1[..., None])
        o1 = jnp.einsum("gnm,gmd->gnd", p1, v_land)
        acc = (o1, m1, p1.sum(axis=-1))

    if include_routed:
        idx = mita_topk_indices_b(scores, kk)  # [G, m, kk]
        ke = gather_rows(k, idx)  # [G, m, kk, d]
        ve = gather_rows(v, idx)
        e = mita_routing_b(q, q_land, s)  # [G, n, s]
        # Gather each query's routed experts: operand rows are experts.
        ke_q = gather_rows(ke.reshape(g, m_of(q_land), kk * d), e).reshape(g, n, s * kk, d)
        ve_q = gather_rows(ve.reshape(g, m_of(q_land), kk * d), e).reshape(g, n, s * kk, d)
        s_rt = jnp.einsum("gnd,gnpd->gnp", q, ke_q) / scale
        m2 = s_rt.max(axis=-1)
        p2 = jnp.exp(s_rt - m2[..., None])
        o2 = jnp.einsum("gnp,gnpd->gnd", p2, ve_q)
        branch = (o2, m2, p2.sum(axis=-1))
        if acc is None:
            acc = branch
        else:
            o1, m1, l1 = acc
            o2, m2, l2 = branch
            mx = jnp.maximum(m1, m2)
            a1 = jnp.exp(m1 - mx)[..., None]
            a2 = jnp.exp(m2 - mx)[..., None]
            acc = (
                o1 * a1 + o2 * a2,
                mx,
                (l1 * jnp.exp(m1 - mx) + l2 * jnp.exp(m2 - mx)),
            )

    o, _, l = acc
    return o / l[..., None]


def m_of(q_land: jax.Array) -> int:
    """Landmark count from a batched [G, m, d] landmark tensor."""
    return q_land.shape[1]


# ---------------------------------------------------------------------------
# Multi-head wrapper.
# ---------------------------------------------------------------------------


def split_heads(x: jax.Array, heads: int) -> jax.Array:
    """[N, D] -> [H, N, D/H]."""
    n, dd = x.shape
    return x.reshape(n, heads, dd // heads).transpose(1, 0, 2)


def merge_heads(x: jax.Array) -> jax.Array:
    """[H, N, d] -> [N, H*d]."""
    h, n, d = x.shape
    return x.transpose(1, 0, 2).reshape(n, h * d)


def multihead(fn, q: jax.Array, k: jax.Array, v: jax.Array, heads: int, **kwargs) -> jax.Array:
    """Apply a single-head attention fn per head. q,k,v: [N, D] -> [N, D]."""
    qs, ks, vs = (split_heads(x, heads) for x in (q, k, v))
    out = jax.vmap(lambda a, b, c: fn(a, b, c, **kwargs))(qs, ks, vs)
    return merge_heads(out)
