"""Mixture-of-Top-k Attention (MiTA) — Pallas kernel + host wrapper.

This is the TPU re-think of the paper's Algorithm 1 (which targets GPU
varlen FlashAttention with ``cu_seqlens``):

  * the per-expert gather (Alg. 1 line 7) is hoisted out of the kernel to
    XLA ``take``, so the kernel streams *dense* ``[m, k, d]`` expert tensors
    HBM→VMEM via BlockSpec (no random access inside the kernel);
  * routing (line 13) sorts queries by expert assignment and packs them into
    a static ``[m, cap, d]`` tensor (cap = per-expert query capacity), which
    keeps the Pallas grid static — the TPU substitute for varlen batches;
  * the shared-expert and routed-expert branches are fused inside one grid
    step with the online-softmax recurrence (line 16), so each query sees a
    single softmax over the concatenation [Q̃ | K^(e(q))] exactly as Eq. (10);
  * queries that overflow their expert's capacity fall back to the
    shared-expert-only output (computed densely, O(N·m)); with the default
    cap_factor=2 the overflow rate is negligible (measured in tests) and the
    kernel is *exact* vs kernels.ref.mita_attention_ref whenever no query
    overflows.

Only s=1 (one routed expert per query, the paper's setting) is supported on
the kernel path; the reference implements general s.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _mita_kernel(qs_ref, ke_ref, ve_ref, qt_ref, vt_ref, o_ref, *, scale):
    """One (expert, q_block) grid step.

    qs_ref: [1, bq, d]  queries routed to this expert (packed, zero-padded)
    ke_ref/ve_ref: [1, k, d]  this expert's top-k key/value pairs
    qt_ref/vt_ref: [m, d]  landmark queries/values (the shared expert)
    o_ref:  [1, bq, d]
    """
    q = qs_ref[0].astype(jnp.float32)  # [bq, d]
    qt = qt_ref[...].astype(jnp.float32)  # [m, d]
    vt = vt_ref[...].astype(jnp.float32)
    ke = ke_ref[0].astype(jnp.float32)  # [k, d]
    ve = ve_ref[0].astype(jnp.float32)

    # Shared-expert branch: logits over the m landmark keys.
    s1 = jnp.dot(q, qt.T, preferred_element_type=jnp.float32) * scale  # [bq, m]
    m1 = s1.max(axis=-1)
    p1 = jnp.exp(s1 - m1[:, None])
    acc = jnp.dot(p1, vt, preferred_element_type=jnp.float32)  # [bq, d]
    den = p1.sum(axis=-1)

    # Routed-expert branch, combined via the online-softmax rescale.
    s2 = jnp.dot(q, ke.T, preferred_element_type=jnp.float32) * scale  # [bq, k]
    m2 = jnp.maximum(m1, s2.max(axis=-1))
    alpha = jnp.exp(m1 - m2)
    p2 = jnp.exp(s2 - m2[:, None])
    acc = acc * alpha[:, None] + jnp.dot(p2, ve, preferred_element_type=jnp.float32)
    den = den * alpha + p2.sum(axis=-1)

    o_ref[0] = (acc / den[:, None]).astype(o_ref.dtype)


def _capacity(n: int, m: int, cap_factor: int, block_q: int) -> int:
    """Per-expert query capacity, rounded up to a block_q multiple."""
    base = -(-n // m) * cap_factor
    return -(-base // block_q) * block_q


def mita_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_land: jax.Array,
    kk: int,
    *,
    cap_factor: int = 2,
    block_q: int = 64,
    return_aux: bool = False,
):
    """MiTA for one head via the Pallas kernel. q,k,v: [N, d] -> [N, d].

    q_land: [m, d] landmark queries (already extracted — see
    ref.extract_landmarks). kk = expert width (top-k).
    """
    n, d = q.shape
    m = q_land.shape[0]
    scale = 1.0 / (d**0.5)
    cap = _capacity(n, m, cap_factor, block_q)

    # --- L2 prologue (fused by XLA, outside the kernel) -------------------
    scores = ref.mita_scores(k, q_land)  # [N, m]
    v_land = ref.mita_landmark_values(scores, v)  # [m, d]
    idx = ref.mita_topk_indices(scores, kk)  # [m, kk]
    ke = jnp.take(k, idx, axis=0)  # [m, kk, d]
    ve = jnp.take(v, idx, axis=0)

    e = jnp.argmax(q @ q_land.T, axis=-1)  # [N] expert assignment (s=1)
    order = jnp.argsort(e, stable=True)
    e_sorted = e[order]
    counts = jnp.bincount(e, length=m)  # queries per expert
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n) - starts[e_sorted]  # position within expert
    keep = rank < cap
    slot = e_sorted * cap + jnp.minimum(rank, cap - 1)  # [N]
    slot_safe = jnp.where(keep, slot, m * cap)  # overflow -> spare row

    qs = (
        jnp.zeros((m * cap + 1, d), q.dtype)
        .at[slot_safe]
        .set(q[order])[:-1]
        .reshape(m, cap, d)
    )

    # --- Pallas kernel over the static (expert, q_block) grid -------------
    kernel = functools.partial(_mita_kernel, scale=scale)
    out_packed = pl.pallas_call(
        kernel,
        grid=(m, cap // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, kk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, kk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((m, d), lambda i, j: (0, 0)),
            pl.BlockSpec((m, d), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, cap, d), q.dtype),
        interpret=True,
    )(qs, ke, ve, q_land, v_land)

    # --- Scatter back + shared-only fallback for overflow queries ---------
    out_sorted = out_packed.reshape(m * cap, d)[slot]  # [N, d] (sorted order)
    shared_only = (
        jax.nn.softmax((q @ q_land.T) * scale, axis=-1) @ v_land
    )  # [N, d] in original order
    picked = jnp.where(keep[:, None], out_sorted, shared_only[order])
    out = jnp.zeros_like(q).at[order].set(picked)

    if return_aux:
        overflow = n - keep.sum()
        return out, {"overflow": overflow, "counts": counts, "idx": idx, "e": e}
    return out


def _mita_kernel_b(qs_ref, ke_ref, ve_ref, qt_ref, vt_ref, o_ref, *, scale):
    """Batched-grid variant of [`_mita_kernel`]: landmark blocks are [1,m,d]
    (selected per grid step via index_map `i // m`)."""
    q = qs_ref[0].astype(jnp.float32)  # [bq, d]
    qt = qt_ref[0].astype(jnp.float32)  # [m, d]
    vt = vt_ref[0].astype(jnp.float32)
    ke = ke_ref[0].astype(jnp.float32)  # [k, d]
    ve = ve_ref[0].astype(jnp.float32)

    s1 = jnp.dot(q, qt.T, preferred_element_type=jnp.float32) * scale
    m1 = s1.max(axis=-1)
    p1 = jnp.exp(s1 - m1[:, None])
    acc = jnp.dot(p1, vt, preferred_element_type=jnp.float32)
    den = p1.sum(axis=-1)

    s2 = jnp.dot(q, ke.T, preferred_element_type=jnp.float32) * scale
    m2 = jnp.maximum(m1, s2.max(axis=-1))
    alpha = jnp.exp(m1 - m2)
    p2 = jnp.exp(s2 - m2[:, None])
    acc = acc * alpha[:, None] + jnp.dot(p2, ve, preferred_element_type=jnp.float32)
    den = den * alpha + p2.sum(axis=-1)

    o_ref[0] = (acc / den[:, None]).astype(o_ref.dtype)


def mita_attention_pallas_b(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_land: jax.Array,
    kk: int,
    *,
    cap_factor: int = 2,
    block_q: int = 64,
    return_aux: bool = False,
):
    """Batched MiTA Pallas path: q,k,v [G,N,d], q_land [G,m,d] -> [G,N,d].

    Identical math to [`mita_attention_pallas`] but with batch and heads
    merged into the leading G axis and every gather/scatter expressed as a
    flat non-batched op — the AOT interchange (xla_extension 0.5.1) rejects
    gathers with operand_batching_dims, so this path never vmaps them.
    """
    g, n, d = q.shape
    m = q_land.shape[1]
    scale = 1.0 / (d**0.5)
    cap = _capacity(n, m, cap_factor, block_q)

    # --- prologue (fused by XLA, outside the kernel) -----------------------
    scores = ref.mita_scores_b(k, q_land)  # [G, N, m]
    v_land = ref.mita_landmark_values_b(scores, v)  # [G, m, d]
    idx = ref.mita_topk_indices_b(scores, kk)  # [G, m, kk]
    ke = ref.gather_rows(k, idx)  # [G, m, kk, d]
    ve = ref.gather_rows(v, idx)

    e = jnp.argmax(jnp.einsum("gnd,gmd->gnm", q, q_land), axis=-1)  # [G, N]
    # Rank within (g, expert) without take_along_axis: one-hot + cumsum.
    onehot = (e[..., None] == jnp.arange(m)).astype(jnp.int32)  # [G, N, m]
    cum = jnp.cumsum(onehot, axis=1) - onehot
    rank = (cum * onehot).sum(axis=-1)  # [G, N]
    keep = rank < cap
    slot = jnp.arange(g, dtype=e.dtype)[:, None] * (m * cap) + e * cap + jnp.minimum(rank, cap - 1)
    slot_safe = jnp.where(keep, slot, g * m * cap)  # overflow -> spare row

    qs = (
        jnp.zeros((g * m * cap + 1, d), q.dtype)
        .at[slot_safe.reshape(-1)]
        .set(q.reshape(-1, d))[:-1]
        .reshape(g * m, cap, d)
    )

    kernel = functools.partial(_mita_kernel_b, scale=scale)
    out_packed = pl.pallas_call(
        kernel,
        grid=(g * m, cap // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, kk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, kk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, m, d), lambda i, j: (i // m, 0, 0)),
            pl.BlockSpec((1, m, d), lambda i, j: (i // m, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((g * m, cap, d), q.dtype),
        interpret=True,
    )(qs, ke.reshape(g * m, kk, d), ve.reshape(g * m, kk, d), q_land, v_land)

    # --- scatter back + shared-only fallback -------------------------------
    out_q = out_packed.reshape(g * m * cap, d)[slot.reshape(-1)].reshape(g, n, d)
    shared_logits = jnp.einsum("gnd,gmd->gnm", q, q_land) * scale
    shared_only = jnp.einsum("gnm,gmd->gnd", jax.nn.softmax(shared_logits, axis=-1), v_land)
    out = jnp.where(keep[..., None], out_q, shared_only)

    if return_aux:
        overflow = (~keep).sum()
        return out, {"overflow": overflow, "idx": idx, "e": e}
    return out


def mita_attention_b(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_land: jax.Array,
    kk: int,
    s: int = 1,
    *,
    use_pallas: bool = False,
    include_shared: bool = True,
    include_routed: bool = True,
    cap_factor: int = 2,
    block_q: int = 64,
) -> jax.Array:
    """Batched dispatching entry point used by the L2 model.

    use_pallas=False (training artifacts): exact differentiable reference
    math, fused by XLA. use_pallas=True (inference/serving artifacts): the
    batched Pallas kernel path (s=1, shared+routed only).
    """
    if use_pallas and include_shared and include_routed and s == 1:
        return mita_attention_pallas_b(
            q, k, v, q_land, kk, cap_factor=cap_factor, block_q=block_q
        )
    return ref.mita_attention_ref_b(
        q,
        k,
        v,
        q_land,
        kk,
        s=s,
        include_shared=include_shared,
        include_routed=include_routed,
    )


def mita_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_land: jax.Array,
    kk: int,
    s: int = 1,
    *,
    use_pallas: bool = False,
    include_shared: bool = True,
    include_routed: bool = True,
    cap_factor: int = 2,
    block_q: int = 64,
) -> jax.Array:
    """Single-head entry point (tests / reference use)."""
    if use_pallas and include_shared and include_routed and s == 1:
        return mita_attention_pallas(
            q, k, v, q_land, kk, cap_factor=cap_factor, block_q=block_q
        )
    return ref.mita_attention_ref(
        q,
        k,
        v,
        q_land,
        kk,
        s=s,
        include_shared=include_shared,
        include_routed=include_routed,
    )
