"""L1 — Pallas kernels for MiTA and the standard-attention baseline.

`ref` holds the pure-jnp oracles (also the differentiable training path);
`mita` the MiTA kernel + dispatcher; `attention` the FlashAttention-style
tiled baseline. Everything lowers with interpret=True (CPU PJRT target).
"""

from . import attention, mita, ref  # noqa: F401
