"""Shared configuration dataclasses for the MiTA compile path.

These mirror the Rust-side `config` module (rust/src/config/): the AOT
pipeline (aot.py) reads experiment specs, instantiates these configs, and
records them in artifacts/manifest.json so the Rust coordinator knows the
exact shapes/layouts of every compiled computation.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


# Attention mechanism kinds. `mita_route` / `mita_compress` are the paper's
# route-only / compress-only ablations (Tab. 6); `agent` is Agent Attention
# (= MiTA compress-only with softmax routing weights); `linear` is
# kernelized linear attention (Katharopoulos et al., 2020).
ATTENTION_KINDS = (
    "standard",
    "mita",
    "mita_route",
    "mita_compress",
    "agent",
    "linear",
)

# Landmark-extraction strategies ablated in Tab. 6.
LANDMARK_MODES = ("pool2d", "pool1d", "random", "learned")


@dataclass(frozen=True)
class AttentionConfig:
    """Configuration of one attention mechanism instance.

    Attributes:
      kind: one of ATTENTION_KINDS.
      m: number of landmark queries / fast-weight experts.
      k: key-value pairs gathered per expert (expert width).
      s: routed experts per query (paper uses s=1 throughout).
      landmark: landmark extraction mode (Tab. 6 ablation).
      cap_factor: per-expert query capacity multiplier for the static-shape
        kernel path; capacity = ceil(N / m) * cap_factor. Queries overflowing
        an expert's capacity fall back to the shared expert only.
      use_pallas: route the forward through the Pallas kernel (inference
        artifacts) instead of the fused-XLA reference math (training
        artifacts — Pallas has no autodiff rule).
    """

    kind: str = "mita"
    m: int = 25
    k: int = 25
    s: int = 1
    landmark: str = "pool2d"
    cap_factor: int = 2
    use_pallas: bool = False

    def __post_init__(self):
        assert self.kind in ATTENTION_KINDS, self.kind
        assert self.landmark in LANDMARK_MODES, self.landmark
        assert self.s >= 1


@dataclass(frozen=True)
class ModelConfig:
    """A transformer model for one of the paper's task families.

    task:
      "cls_image"  — ViT classifier over synthetic images (Tabs. 2/3/6/7).
      "seg_image"  — ViT + linear seg head, per-patch labels (Tab. 4).
      "lra"        — token-sequence classifier (Tab. 5 / Fig. 5).
    """

    task: str = "cls_image"
    depth: int = 4
    dim: int = 128
    heads: int = 4
    mlp_ratio: float = 4.0
    num_classes: int = 10
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    # image tasks
    image_hw: Tuple[int, int] = (56, 56)
    patch: int = 4
    channels: int = 3
    # lra tasks
    seq_len: int = 1024
    vocab: int = 32
    pool: str = "mean"  # lra classifier pooling: "mean" | "cls"
    # extra components from Tab. 2 footnotes
    dwc: bool = False  # depth-wise conv on values (DWC variant)
    gate: bool = False  # data-dependent output gating (Gate variant)

    @property
    def grid_hw(self) -> Tuple[int, int]:
        return (self.image_hw[0] // self.patch, self.image_hw[1] // self.patch)

    @property
    def num_tokens(self) -> int:
        if self.task == "lra":
            return self.seq_len
        gh, gw = self.grid_hw
        return gh * gw

    def __post_init__(self):
        assert self.task in ("cls_image", "seg_image", "lra"), self.task
        assert self.dim % self.heads == 0
        if self.task != "lra":
            assert self.image_hw[0] % self.patch == 0
            assert self.image_hw[1] % self.patch == 0


@dataclass(frozen=True)
class TrainConfig:
    """AdamW training hyperparameters baked into the train_step artifact."""

    lr: float = 1e-3
    weight_decay: float = 0.05
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    warmup_steps: int = 50
    total_steps: int = 500  # cosine decay horizon
    label_smoothing: float = 0.0
    grad_clip: float = 1.0
    batch_size: int = 32


def config_to_dict(cfg) -> dict:
    """Recursively convert a (nested) dataclass to a JSON-safe dict."""
    return dataclasses.asdict(cfg)


def config_id(model: ModelConfig, train: Optional[TrainConfig] = None) -> str:
    """Stable short identifier for a config, used in artifact file names."""
    import hashlib

    blob = json.dumps(
        {"model": config_to_dict(model), "train": config_to_dict(train) if train else None},
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:12]
