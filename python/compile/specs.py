"""Experiment bundle registry — the single source of truth for every AOT
artifact the Rust coordinator consumes.

A *bundle* is one (model config, train config, batch shapes) tuple; aot.py
lowers its computations (init / train_step / eval_step / predict /
analysis) to HLO text and records everything in artifacts/manifest.json.
The Rust table/figure binaries iterate bundles by name prefix (see
DESIGN.md §5 experiment index).

Scales are CPU-calibrated stand-ins for the paper's workloads (DESIGN.md §3
substitutions): the synthetic-image corpus replaces ImageNet-1K/ADE20K and
the synthetic LRA generators replace LRA — sequence geometry and the
m/k/N ratios match the paper's settings.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from .configs import AttentionConfig, ModelConfig, TrainConfig


@dataclass(frozen=True)
class Bundle:
    """One experiment configuration to AOT-compile."""

    name: str
    model: ModelConfig
    train: TrainConfig
    # Which computations to emit for this bundle.
    emit: Tuple[str, ...] = ("init", "train_step", "eval_step")
    # Free-form metadata surfaced to Rust (steps, corpus params, table id).
    meta: Dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Image-classification family (Tabs. 2/3/6/7, Figs. 9/10).
#
# Paper: DeiT-T on ImageNet-1K, N=196 tokens, m=k=25.  Here: 32x32x3
# synthetic corpus, patch 4 -> N=64 tokens, m=k=16 — the attended-pairs
# ratio (m+ks)/N = 32/64 = 0.5 mirrors moderate sparsity; m*k/N = 4 vs the
# paper's 3.2.
# ---------------------------------------------------------------------------

IMG_HW = (32, 32)
IMG_PATCH = 4
IMG_CLASSES = 10
IMG_DEPTH = 3
IMG_DIM = 64
IMG_HEADS = 4
IMG_BATCH = 32

IMG_TRAIN = TrainConfig(
    lr=1e-3,
    weight_decay=0.05,
    warmup_steps=30,
    total_steps=250,
    label_smoothing=0.1,
    batch_size=IMG_BATCH,
)


def _img_model(kind: str, m: int = 16, k: int = 16, landmark: str = "pool2d", **kw) -> ModelConfig:
    return ModelConfig(
        task="cls_image",
        depth=IMG_DEPTH,
        dim=IMG_DIM,
        heads=IMG_HEADS,
        num_classes=IMG_CLASSES,
        image_hw=IMG_HW,
        patch=IMG_PATCH,
        channels=3,
        attention=AttentionConfig(kind=kind, m=m, k=k, landmark=landmark),
        **kw,
    )


def table2_bundles() -> List[Bundle]:
    """Tab. 2 — from-scratch training, attention mechanism varied only."""
    rows = [
        ("std", _img_model("standard")),
        ("linear", _img_model("linear")),
        ("agent", _img_model("agent")),
        ("mita", _img_model("mita")),
        ("mita_dwc", _img_model("mita", dwc=True)),
        ("mita_dwc_gate", _img_model("mita", dwc=True, gate=True)),
    ]
    meta = {"table": "2", "steps": IMG_TRAIN.total_steps, "eval_batches": 16}
    return [
        Bundle(name=f"t2_{tag}", model=mc, train=IMG_TRAIN, meta={**meta, "row": tag})
        for tag, mc in rows
    ]


def table6_bundles() -> List[Bundle]:
    """Tab. 6 — ablations: landmark mode, (m, k) grid, scaling strategies."""
    rows: List[Tuple[str, ModelConfig]] = []
    # Landmark extraction ablation (paper: random / learned / 1d / 2d pool).
    for lm in ("random", "learned", "pool1d", "pool2d"):
        rows.append((f"lm_{lm}", _img_model("mita", landmark=lm)))
    # m x k grid (paper: {16,25,36}^2; ours {8,16,32}^2 around default 16).
    for m in (8, 16, 32):
        for k in (8, 16, 32):
            rows.append((f"mk_{m}x{k}", _img_model("mita", m=m, k=k)))
    # Scaling-strategy ablation.
    rows.append(("route_only", _img_model("mita_route", k=32)))  # budget-matched
    rows.append(("compress_only", _img_model("mita_compress", m=32)))
    meta = {"table": "6", "steps": IMG_TRAIN.total_steps, "eval_batches": 16}
    out = []
    seen = set()
    for tag, mc in rows:
        if tag in seen:
            continue
        seen.add(tag)
        out.append(Bundle(name=f"t6_{tag}", model=mc, train=IMG_TRAIN, meta={**meta, "row": tag}))
    return out


def table7_bundles() -> List[Bundle]:
    """Tab. 7 — pretrain with standard attention, finetune with X.

    The pretrain bundle is t2_std (re-used); finetune bundles share its
    parameter layout, so Rust warm-starts them from the t2_std checkpoint.
    """
    ft_train = replace(IMG_TRAIN, lr=3e-4, warmup_steps=10, total_steps=100)
    kinds = [("std", "standard"), ("linear", "linear"), ("agent", "agent"), ("mita", "mita")]
    meta = {"table": "7", "steps": ft_train.total_steps, "warm_start": "t2_std", "eval_batches": 16}
    return [
        Bundle(name=f"t7_{tag}", model=_img_model(kind), train=ft_train, meta={**meta, "row": tag})
        for tag, kind in kinds
    ]


def fig9_bundles() -> List[Bundle]:
    """Fig. 9 — train-with-X / infer-with-Y swap matrix.

    Training artifacts come from t2_*; this only adds eval_step artifacts
    for each inference attention (same param layout), marked eval-only.
    """
    kinds = [("std", "standard"), ("agent", "agent"), ("mita", "mita")]
    meta = {"figure": "9", "eval_batches": 16}
    return [
        Bundle(
            name=f"f9_eval_{tag}",
            model=_img_model(kind),
            train=IMG_TRAIN,
            emit=("eval_step",),
            meta={**meta, "row": tag},
        )
        for tag, kind in kinds
    ]


def fig10_bundles() -> List[Bundle]:
    """Fig. 10 — (m, k) generalization grid at inference, eval-only."""
    grid = (4, 8, 16, 32)
    meta = {"figure": "10", "eval_batches": 16, "trained_on": "t2_mita"}
    out = []
    for m in grid:
        for k in grid:
            out.append(
                Bundle(
                    name=f"f10_eval_m{m}k{k}",
                    model=_img_model("mita", m=m, k=k),
                    train=IMG_TRAIN,
                    emit=("eval_step",),
                    meta={**meta, "m": m, "k": k},
                )
            )
    return out


def analysis_bundles() -> List[Bundle]:
    """Figs. 3/4/8 — routing internals of the trained t2_mita model."""
    return [
        Bundle(
            name="fig_analysis_mita",
            model=_img_model("mita"),
            train=IMG_TRAIN,
            emit=("analysis",),
            meta={"figure": "3/4/8", "trained_on": "t2_mita"},
        )
    ]


# ---------------------------------------------------------------------------
# Segmentation family (Tab. 4) — synthetic dense prediction.
#
# Paper: ADE20K at 512^2/640^2 -> N=1024/1600 tokens, m=k=49. Here: 64x64
# images, patch 4 -> N=256 tokens, m=k=25; ▽ = backbone attention swapped
# at eval time (we also train natively for the loss curve).
# ---------------------------------------------------------------------------

SEG_TRAIN = replace(IMG_TRAIN, total_steps=200, batch_size=16, label_smoothing=0.0)
SEG_CLASSES = 8


def _seg_model(kind: str, m: int = 25, k: int = 25) -> ModelConfig:
    return ModelConfig(
        task="seg_image",
        depth=IMG_DEPTH,
        dim=IMG_DIM,
        heads=IMG_HEADS,
        num_classes=SEG_CLASSES,
        image_hw=(64, 64),
        patch=4,
        channels=3,
        attention=AttentionConfig(kind=kind, m=m, k=k, landmark="pool2d"),
    )


def table4_bundles() -> List[Bundle]:
    meta = {"table": "4", "steps": SEG_TRAIN.total_steps, "eval_batches": 16}
    return [
        Bundle(name="t4_std", model=_seg_model("standard"), train=SEG_TRAIN, meta={**meta, "row": "std"}),
        Bundle(name="t4_mita", model=_seg_model("mita"), train=SEG_TRAIN, meta={**meta, "row": "mita"}),
        # ▽ row: eval the std-trained params with MiTA attention.
        Bundle(
            name="t4_mita_swap",
            model=_seg_model("mita"),
            train=SEG_TRAIN,
            emit=("eval_step",),
            meta={**meta, "row": "mita_swap", "trained_on": "t4_std"},
        ),
    ]


# ---------------------------------------------------------------------------
# LRA family (Tab. 5) — five synthetic long-sequence tasks.
#
# Paper lengths 1K-4K; ours 256-1024 (CPU), same relative geometry:
# m=k chosen so m+ks << N.
# ---------------------------------------------------------------------------

LRA_TRAIN = TrainConfig(
    lr=5e-4,
    weight_decay=0.01,
    warmup_steps=20,
    total_steps=100,
    batch_size=8,
)

# task -> (seq_len, vocab, classes, m=k)
LRA_TASKS: Dict[str, Tuple[int, int, int, int]] = {
    "listops": (256, 16, 10, 16),
    "text": (512, 64, 2, 32),
    "retrieval": (512, 64, 2, 32),
    "image": (256, 32, 10, 16),
    "pathfinder": (256, 4, 2, 16),
}

LRA_METHODS = ("standard", "mita", "mita_route", "agent", "linear")


def _lra_model(task: str, kind: str) -> ModelConfig:
    n, vocab, classes, mk = LRA_TASKS[task]
    k = mk * 2 if kind == "mita_route" else mk  # route-only: budget-matched
    return ModelConfig(
        task="lra",
        depth=2,
        dim=64,
        heads=2,
        num_classes=classes,
        seq_len=n,
        vocab=vocab,
        attention=AttentionConfig(kind=kind, m=mk, k=k, landmark="pool1d"),
    )


def table5_bundles() -> List[Bundle]:
    out = []
    for task in LRA_TASKS:
        for kind in LRA_METHODS:
            meta = {
                "table": "5",
                "task": task,
                "method": kind,
                "steps": LRA_TRAIN.total_steps,
                "eval_batches": 16,
            }
            out.append(
                Bundle(name=f"t5_{task}_{kind}", model=_lra_model(task, kind), train=LRA_TRAIN, meta=meta)
            )
    return out


# ---------------------------------------------------------------------------
# Serving / throughput family (Fig. 5) — forward-only artifacts.
#
# Paper: 3-layer transformer, d=128, N up to very long; batch tuned.
# ---------------------------------------------------------------------------

FIG5_LENS = (512, 1024, 2048, 4096)
FIG5_BATCH = 2


def _fig5_model(kind: str, n: int, use_pallas: bool = False) -> ModelConfig:
    mk = 64
    return ModelConfig(
        task="lra",
        depth=3,
        dim=128,
        heads=4,
        num_classes=10,
        seq_len=n,
        vocab=64,
        attention=AttentionConfig(kind=kind, m=mk, k=mk, landmark="pool1d", use_pallas=use_pallas),
    )


def fig5_bundles() -> List[Bundle]:
    out = []
    for n in FIG5_LENS:
        for kind in ("standard", "mita"):
            meta = {"figure": "5", "seq_len": n, "method": kind, "batch": FIG5_BATCH}
            out.append(
                Bundle(
                    name=f"f5_{kind}_n{n}",
                    model=_fig5_model(kind, n),
                    train=LRA_TRAIN,
                    emit=("init", "predict"),
                    meta=meta,
                )
            )
    # Pallas-kernel serving variants (exercises the L1 kernel on the
    # request path at a moderate N).
    for kind in ("standard", "mita"):
        meta = {"figure": "5", "seq_len": 1024, "method": f"{kind}_pallas", "batch": FIG5_BATCH}
        out.append(
            Bundle(
                name=f"f5_{kind}_pallas_n1024",
                model=_fig5_model(kind, 1024, use_pallas=True),
                train=LRA_TRAIN,
                emit=("predict",),
                meta=meta,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Quickstart bundle — tiny, compiled fast; used by examples/quickstart.rs.
# ---------------------------------------------------------------------------


def quickstart_bundles() -> List[Bundle]:
    mc = ModelConfig(
        task="cls_image",
        depth=2,
        dim=64,
        heads=4,
        num_classes=10,
        image_hw=(32, 32),
        patch=8,
        channels=3,
        attention=AttentionConfig(kind="mita", m=4, k=4, landmark="pool2d"),
    )
    tc = replace(IMG_TRAIN, total_steps=80, warmup_steps=5, batch_size=16)
    return [
        Bundle(
            name="quickstart",
            model=mc,
            train=tc,
            emit=("init", "train_step", "eval_step", "predict"),
            meta={"steps": 80, "eval_batches": 8, "noise_sigma": 0.1},
        )
    ]


def all_bundles() -> List[Bundle]:
    bundles: List[Bundle] = []
    bundles += quickstart_bundles()
    bundles += table2_bundles()
    bundles += table4_bundles()
    bundles += table5_bundles()
    bundles += table6_bundles()
    bundles += table7_bundles()
    bundles += fig5_bundles()
    bundles += fig9_bundles()
    bundles += fig10_bundles()
    bundles += analysis_bundles()
    names = [b.name for b in bundles]
    assert len(names) == len(set(names)), "duplicate bundle names"
    return bundles
