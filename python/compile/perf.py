"""L1 performance model: VMEM footprint + MXU utilization estimates for the
Pallas kernels' BlockSpecs.

interpret=True gives CPU-numpy timings that are NOT a TPU proxy, so the
structural quantities below are what we optimize (DESIGN.md §7):

  * VMEM bytes per grid step must fit the ~16 MiB/core budget (we target
    <= 4 MiB to leave room for double buffering);
  * MXU utilization is estimated from tile shapes: a [p, q] x [q, r] matmul
    runs the 128x128 systolic array at efficiency
    (p/ceil128(p)) * (q/ceil128(q)) * (r/ceil128(r)) — small tiles waste
    lanes;
  * arithmetic intensity (FLOPs / HBM bytes) tells whether a config is
    memory- or compute-bound against the ~940 GB/s : 275 TFLOP/s (bf16)
    roofline ratio of a TPU v4 core.

`mita_kernel_report` / `flash_kernel_report` are consumed by
tests/test_perf.py and quoted in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

F32 = 4
MXU = 128  # systolic array edge
VMEM_BUDGET = 16 * 2**20
VMEM_TARGET = 4 * 2**20


def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def mxu_efficiency(p: int, q: int, r: int) -> float:
    """Fraction of MXU lanes doing useful work for a [p,q]x[q,r] matmul."""
    return (p / _ceil_to(p, MXU)) * (q / _ceil_to(q, MXU)) * (r / _ceil_to(r, MXU))


@dataclass
class KernelReport:
    name: str
    vmem_bytes: int
    flops: float
    hbm_bytes: float
    mxu_eff: float

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def fits_target(self) -> bool:
        return self.vmem_bytes <= VMEM_TARGET

    def as_dict(self) -> Dict:
        return {
            "name": self.name,
            "vmem_bytes": self.vmem_bytes,
            "vmem_mib": round(self.vmem_bytes / 2**20, 3),
            "flops_per_step": self.flops,
            "hbm_bytes_per_step": self.hbm_bytes,
            "arithmetic_intensity": round(self.arithmetic_intensity, 2),
            "mxu_eff": round(self.mxu_eff, 3),
            "fits_4mib_target": self.fits_target,
        }


def mita_kernel_report(
    n: int, d: int, m: int, kk: int, block_q: int = 64, cap_factor: int = 2, dtype_bytes: int = F32
) -> KernelReport:
    """One (expert, q_block) grid step of kernels/mita.py::_mita_kernel_b.

    VMEM residents: q block [bq, d], expert kv 2x[kk, d], landmarks
    2x[m, d], output [bq, d], plus f32 accumulators [bq, d] + 2x[bq].
    """
    bq = block_q
    resid = (
        bq * d  # q block
        + 2 * kk * d  # ke, ve
        + 2 * m * d  # qt, vt
        + bq * d  # out
    ) * dtype_bytes + (bq * d + 2 * bq) * F32  # accumulators are f32
    # Two matmul pairs: [bq,d]x[d,m] + [bq,m]x[m,d]; [bq,d]x[d,kk] + [bq,kk]x[kk,d].
    flops = 2.0 * bq * d * m * 2 + 2.0 * bq * d * kk * 2
    # HBM traffic per step: stream q block + out; expert kv amortized over
    # cap/bq steps of the same expert; landmarks amortized over whole grid.
    steps_per_expert = max(_capacity(n, m, cap_factor, bq) // bq, 1)
    hbm = (2 * bq * d + (2 * kk * d) / steps_per_expert) * dtype_bytes
    # Utilization: weighted by FLOPs of each matmul shape.
    e1 = mxu_efficiency(bq, d, m)
    e2 = mxu_efficiency(bq, d, kk)
    w1 = m / (m + kk)
    eff = e1 * w1 + e2 * (1 - w1)
    return KernelReport("mita", resid, flops, hbm, eff)


def flash_kernel_report(n: int, d: int, block_q: int = 128, block_k: int = 128) -> KernelReport:
    """One (q_block, k_block) grid step of kernels/attention.py."""
    bq, bk = min(block_q, n), min(block_k, n)
    resid = (bq * d + 2 * bk * d + bq * d) * F32 + (bq * d + 2 * bq) * F32
    flops = 2.0 * bq * d * bk * 2
    hbm = (2 * bk * d + (2 * bq * d) / max(n // bk, 1)) * F32
    eff = mxu_efficiency(bq, d, bk)
    return KernelReport("flash", resid, flops, hbm, eff)


def _capacity(n: int, m: int, cap_factor: int, block_q: int) -> int:
    base = -(-n // m) * cap_factor
    return -(-base // block_q) * block_q


def sweep_block_q(n: int, d: int, m: int, kk: int) -> Dict[int, Dict]:
    """Block-size sweep used by the §Perf iteration log."""
    return {bq: mita_kernel_report(n, d, m, kk, block_q=bq).as_dict() for bq in (8, 16, 32, 64, 128, 256)}


def main() -> None:
    import json

    configs = [
        ("paper ViT-T (N=196, d=64, m=k=25)", 196, 64, 25, 25),
        ("repo image (N=64, d=16, m=k=16)", 64, 16, 16, 16),
        ("repo LRA (N=512, d=32, m=k=32)", 512, 32, 32, 32),
        ("fig5 large (N=4096, d=32, m=k=64)", 4096, 32, 64, 64),
    ]
    out = {}
    for name, n, d, m, kk in configs:
        out[name] = {
            "mita": mita_kernel_report(n, d, m, kk).as_dict(),
            "flash_baseline": flash_kernel_report(n, d).as_dict(),
            "block_q_sweep": sweep_block_q(n, d, m, kk),
        }
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
